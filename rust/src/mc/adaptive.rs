//! Adaptive-precision Monte-Carlo: run chunks through a sequential
//! stopping rule instead of a fixed trial count.
//!
//! The estimand is the ensemble SNR in dB (eq. 10/11). Each chunk is an
//! independent sub-ensemble on its own [`super::chunk_seed`] stream, so
//! the per-chunk `snr_a_total_db` / `snr_t_db` estimates are i.i.d.
//! batch means; the rule runs chunks until the 95% confidence half-width
//! of *both* batch-mean series fits the requested target (or the trial
//! cap is reached). The reported measurement pools every trial into one
//! [`SnrAccumulator`], which is strictly tighter than the batch-mean CI
//! it stopped on.
//!
//! Adaptive runs are a separate cache-key dimension (see
//! `engine::cache::cache_key`): a `--precision` record can never alias a
//! fixed-`--trials` record, whose bit-exact contract stays untouched.

use crate::arch::pvec;
use crate::util::stats::Welford;

use super::{
    chunk_seed, measure, simulate_chunk, ArchKind, InputDist, MeasuredSnr, SnrAccumulator,
    CHUNK_TRIALS,
};

/// Default trial cap for adaptive runs (32x the fixed default of 2048):
/// the stopping rule gives up and reports the widest-case half-width if
/// the target is unreachable within the cap.
pub const ADAPTIVE_MAX_TRIALS: usize = 1 << 16;

/// Minimum batch means before the CI is trusted at all.
const MIN_CHUNKS: usize = 4;

/// Two-sided 95% normal quantile.
const Z_95: f64 = 1.959_963_984_540_054;

/// Result of one adaptive run: pooled measurement plus the stopping
/// rule's own accounting.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveRun {
    /// Pooled over all executed trials (`measured.trials` is the actual
    /// count, a multiple of [`CHUNK_TRIALS`] up to the cap).
    pub measured: MeasuredSnr,
    /// Achieved 95% half-width (dB) of the worse of the two batch-mean
    /// series (pre-ADC `snr_a_total_db`, total `snr_t_db`).
    pub half_width_db: f64,
    /// The requested target half-width (dB).
    pub target_db: f64,
    /// Chunks executed.
    pub chunks: usize,
    /// Whether the target was met before the trial cap.
    pub converged: bool,
}

/// 95% half-width of a batch-mean series (0 until two finite means).
fn ci_half_width(w: &Welford) -> f64 {
    if w.count() < 2 {
        0.0
    } else {
        Z_95 * w.std() / (w.count() as f64).sqrt()
    }
}

/// Run chunks until both SNR estimators' 95% CIs fit `precision_db`, or
/// `max_trials` is exhausted. `max_trials` is rounded up to a whole
/// number of chunks and at least [`MIN_CHUNKS`] of them.
pub fn simulate_adaptive(
    kind: ArchKind,
    params: &[f64; pvec::P],
    precision_db: f64,
    seed: u64,
    dist: InputDist,
    max_trials: usize,
) -> AdaptiveRun {
    assert!(
        precision_db.is_finite() && precision_db > 0.0,
        "precision half-width must be a positive finite dB value"
    );
    let max_chunks = super::n_chunks(max_trials).max(MIN_CHUNKS);
    let mut pooled = SnrAccumulator::new();
    let mut bm_a = Welford::new();
    let mut bm_t = Welford::new();
    let mut half_width = f64::INFINITY;
    let mut chunks = 0;
    let mut converged = false;
    while chunks < max_chunks {
        let _round_span = crate::obs::trace::span_with("adaptive_round", "adaptive", || {
            format!("round {} of <= {max_chunks}", chunks + 1)
        });
        crate::obs::registry::ADAPTIVE_ROUNDS.add(1);
        let out =
            simulate_chunk(kind, params, CHUNK_TRIALS, chunk_seed(seed, chunks as u64), dist);
        pooled.push_chunk(&out);
        let m = measure(&out);
        // noiseless corners measure infinite dB — a chunk mean that is
        // not finite carries no CI information, so only finite batch
        // means feed the rule (an all-infinite series stops at MIN_CHUNKS
        // with half-width 0: the estimate cannot be tightened further)
        if m.snr_a_total_db.is_finite() {
            bm_a.push(m.snr_a_total_db);
        }
        if m.snr_t_db.is_finite() {
            bm_t.push(m.snr_t_db);
        }
        chunks += 1;
        if chunks >= MIN_CHUNKS {
            half_width = ci_half_width(&bm_a).max(ci_half_width(&bm_t));
            if half_width <= precision_db {
                converged = true;
                break;
            }
        }
    }
    AdaptiveRun {
        measured: pooled.finalize(),
        half_width_db: half_width,
        target_db: precision_db,
        chunks,
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::pvec;

    fn noisy_qs(n: usize) -> [f64; pvec::P] {
        let mut p = [0.0; pvec::P];
        p[pvec::IDX_N_ACTIVE] = n as f64;
        p[pvec::IDX_BX] = 6.0;
        p[pvec::IDX_BW] = 6.0;
        p[pvec::IDX_B_ADC] = 8.0;
        p[pvec::QS_IDX_SIGMA_D] = 0.107;
        p[pvec::QS_IDX_K_H] = 55.0;
        p[pvec::QS_IDX_V_C] = 55.0;
        p
    }

    #[test]
    fn loose_target_converges_below_cap() {
        let p = noisy_qs(128);
        let r = simulate_adaptive(ArchKind::Qs, &p, 2.0, 7, InputDist::Uniform, 1 << 14);
        assert!(r.converged, "half_width={}", r.half_width_db);
        assert!(r.half_width_db <= 2.0);
        assert!(r.chunks >= 4);
        assert_eq!(r.measured.trials as usize, r.chunks * CHUNK_TRIALS);
        assert!((r.measured.trials as usize) < (1 << 14));
    }

    #[test]
    fn unreachable_target_stops_at_cap() {
        let p = noisy_qs(64);
        let r = simulate_adaptive(ArchKind::Qs, &p, 1e-9, 7, InputDist::Uniform, 2048);
        assert!(!r.converged);
        assert_eq!(r.chunks, super::super::n_chunks(2048));
        assert!(r.half_width_db > 1e-9);
    }

    #[test]
    fn tighter_target_runs_more_chunks() {
        let p = noisy_qs(64);
        let loose = simulate_adaptive(ArchKind::Qs, &p, 2.0, 3, InputDist::Uniform, 1 << 15);
        let tight = simulate_adaptive(ArchKind::Qs, &p, 0.2, 3, InputDist::Uniform, 1 << 15);
        assert!(tight.chunks >= loose.chunks, "{} < {}", tight.chunks, loose.chunks);
        assert!(tight.half_width_db <= loose.half_width_db);
    }

    #[test]
    fn deterministic_given_seed_and_target() {
        let p = noisy_qs(64);
        let a = simulate_adaptive(ArchKind::Qs, &p, 1.0, 5, InputDist::Uniform, 1 << 13);
        let b = simulate_adaptive(ArchKind::Qs, &p, 1.0, 5, InputDist::Uniform, 1 << 13);
        assert_eq!(a.chunks, b.chunks);
        assert_eq!(a.measured.trials, b.measured.trials);
        assert_eq!(a.measured.snr_t_db.to_bits(), b.measured.snr_t_db.to_bits());
    }

    #[test]
    fn noiseless_corner_terminates() {
        // infinite-dB chunk means carry no CI information; the run must
        // still terminate (at MIN_CHUNKS) instead of spinning to the cap
        let mut p = [0.0; pvec::P];
        p[pvec::IDX_N_ACTIVE] = 32.0;
        p[pvec::IDX_BX] = 4.0;
        p[pvec::IDX_BW] = 4.0;
        p[pvec::IDX_B_ADC] = 14.0;
        p[pvec::QS_IDX_K_H] = 1e9;
        p[pvec::QS_IDX_V_C] = 200.0;
        let r = simulate_adaptive(ArchKind::Qs, &p, 0.5, 1, InputDist::Uniform, 1 << 13);
        assert!(r.converged);
        assert_eq!(r.chunks, 4);
    }

    #[test]
    #[should_panic(expected = "positive finite")]
    fn rejects_nonpositive_precision() {
        let p = noisy_qs(16);
        simulate_adaptive(ArchKind::Qs, &p, 0.0, 1, InputDist::Uniform, 1024);
    }
}
