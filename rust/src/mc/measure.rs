//! Ensemble SNR measurement from the four MC output streams (eq. 7).

use super::McOutput;
use crate::util::stats::{db, Welford};

/// All compute-SNR metrics measured from one Monte-Carlo ensemble.
#[derive(Clone, Copy, Debug, Default)]
pub struct MeasuredSnr {
    pub sigma_yo2: f64,
    pub sigma_qiy2: f64,
    /// Analog noise (y_a - y_fx): eta_e + eta_h.
    pub sigma_eta_a2: f64,
    /// ADC quantization (y_hat - y_a).
    pub sigma_qy2: f64,
    pub sqnr_qiy_db: f64,
    pub snr_a_db: f64,
    /// Pre-ADC SNR_A (noise vs ideal, eq. 10).
    pub snr_a_total_db: f64,
    /// Total SNR_T (eq. 11).
    pub snr_t_db: f64,
    pub trials: u64,
}

/// Streaming accumulator: push MC output chunks as they arrive from the
/// executor (chunks may arrive in any order; variance aggregation is
/// order-independent up to float rounding).
#[derive(Clone, Debug, Default)]
pub struct SnrAccumulator {
    sig: Welford,
    qiy: Welford,
    eta: Welford,
    qy: Welford,
    pre: Welford,
    tot: Welford,
}

impl SnrAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push_chunk(&mut self, out: &McOutput) {
        for i in 0..out.len() {
            let (yi, yfx, ya, yh) =
                (out.y_ideal[i], out.y_fx[i], out.y_a[i], out.y_hat[i]);
            self.sig.push(yi);
            self.qiy.push(yfx - yi);
            self.eta.push(ya - yfx);
            self.qy.push(yh - ya);
            self.pre.push(ya - yi);
            self.tot.push(yh - yi);
        }
    }

    pub fn merge(&mut self, other: &SnrAccumulator) {
        self.sig.merge(&other.sig);
        self.qiy.merge(&other.qiy);
        self.eta.merge(&other.eta);
        self.qy.merge(&other.qy);
        self.pre.merge(&other.pre);
        self.tot.merge(&other.tot);
    }

    pub fn count(&self) -> u64 {
        self.sig.count()
    }

    pub fn finalize(&self) -> MeasuredSnr {
        let s2 = self.sig.variance();
        MeasuredSnr {
            sigma_yo2: s2,
            sigma_qiy2: self.qiy.variance(),
            sigma_eta_a2: self.eta.variance(),
            sigma_qy2: self.qy.variance(),
            sqnr_qiy_db: db(s2 / self.qiy.variance()),
            snr_a_db: db(s2 / self.eta.variance()),
            snr_a_total_db: db(s2 / self.pre.variance()),
            snr_t_db: db(s2 / self.tot.variance()),
            trials: self.sig.count(),
        }
    }
}

pub fn measure(out: &McOutput) -> MeasuredSnr {
    let mut acc = SnrAccumulator::new();
    acc.push_chunk(out);
    acc.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_synthetic_streams() {
        // construct streams with known noise powers
        let mut out = McOutput::default();
        let mut rng = crate::util::rng::Pcg64::new(8);
        for _ in 0..100_000 {
            let yi = rng.normal_scaled(0.0, 3.0);
            let yfx = yi + rng.normal_scaled(0.0, 0.3);
            let ya = yfx + rng.normal_scaled(0.0, 0.3);
            let yh = ya + rng.normal_scaled(0.0, 0.3);
            out.push(yi, yfx, ya, yh);
        }
        let m = measure(&out);
        // each stage adds 0.09 to noise power; signal 9.0 -> 20 dB per stage
        assert!((m.sqnr_qiy_db - 20.0).abs() < 0.2, "{}", m.sqnr_qiy_db);
        assert!((m.snr_a_db - 20.0).abs() < 0.2);
        // pre-ADC: 9/(0.18) = 17 dB; total: 9/0.27 = 15.2 dB
        assert!((m.snr_a_total_db - db(9.0 / 0.18)).abs() < 0.2);
        assert!((m.snr_t_db - db(9.0 / 0.27)).abs() < 0.2);
        assert_eq!(m.trials, 100_000);
    }

    #[test]
    fn snr_t_never_exceeds_components() {
        let mut out = McOutput::default();
        let mut rng = crate::util::rng::Pcg64::new(9);
        for _ in 0..10_000 {
            let yi = rng.normal();
            let yfx = yi + 0.1 * rng.normal();
            let ya = yfx + 0.1 * rng.normal();
            let yh = ya + 0.1 * rng.normal();
            out.push(yi, yfx, ya, yh);
        }
        let m = measure(&out);
        assert!(m.snr_t_db <= m.snr_a_total_db + 0.3);
        assert!(m.snr_a_total_db <= m.sqnr_qiy_db + 0.3);
    }
}
