//! `cargo bench` — throughput/latency benchmarks for every paper
//! table/figure regeneration plus the hot paths under them.
//!
//! Filter by substring: `cargo bench -- fig9` or `cargo bench -- mc_`.
//! Uses the in-repo harness (rust/src/bench). PJRT benches require
//! `make artifacts` and are skipped otherwise.

use std::time::Duration;

use imclim::arch::{pvec, ImcArch, OpPoint, QsArch};
use imclim::bench::{black_box, BenchConfig, Suite};
use imclim::compute::qs::QsModel;
use imclim::coordinator::{run_sweep, Backend, PjrtService, SweepOptions, SweepPoint};
use imclim::engine::Engine;
use imclim::figures::{self, FigCtx};
use imclim::mc::{self, simulate, ArchKind, InputDist};
use imclim::opt::{frontier, optimize, ArchChoice, Constraints, Domain, Objective};
use imclim::tech::TechNode;
use imclim::util::json::{arr, num, obj, s, Json};

fn qs_params(n: f64, sigma_d: f64) -> [f64; pvec::P] {
    let mut p = [0.0; pvec::P];
    p[pvec::IDX_N_ACTIVE] = n;
    p[pvec::IDX_BX] = 6.0;
    p[pvec::IDX_BW] = 6.0;
    p[pvec::IDX_B_ADC] = 8.0;
    p[pvec::QS_IDX_SIGMA_D] = sigma_d;
    p[pvec::QS_IDX_K_H] = 55.0;
    p[pvec::QS_IDX_V_C] = 55.0;
    p
}

fn main() {
    let mut suite = Suite::from_args(BenchConfig {
        warmup: Duration::from_millis(300),
        budget: Duration::from_secs(3),
        min_iters: 3,
        max_iters: 10_000,
    });

    // ---- L3 hot paths: native Monte-Carlo trial throughput ------------
    for (name, kind) in [
        ("mc_qs_n512", ArchKind::Qs),
        ("mc_qr_n512", ArchKind::Qr),
        ("mc_cm_n512", ArchKind::Cm),
    ] {
        let mut p = qs_params(512.0, 0.107);
        if kind == ArchKind::Qr {
            p[pvec::QR_IDX_SIGMA_C] = 0.08;
            p[pvec::QR_IDX_V_C] = 1.0;
        }
        if kind == ArchKind::Cm {
            p[pvec::CM_IDX_SIGMA_D] = 0.107;
            p[pvec::CM_IDX_W_H] = 1.0;
            p[pvec::CM_IDX_V_C] = 0.2;
        }
        let trials = 256;
        let mut seed = 0u64;
        suite.bench(name, trials as f64, || {
            seed += 1;
            black_box(simulate(kind, &p, trials, seed, InputDist::Uniform));
        });
    }

    // correlated-mismatch ablation path
    {
        let mut p = qs_params(512.0, 0.107);
        p[pvec::QS_IDX_MODE] = 1.0;
        suite.bench("mc_qs_n512_correlated", 256.0, || {
            black_box(simulate(ArchKind::Qs, &p, 256, 7, InputDist::Uniform));
        });
    }

    // frozen scalar reference path on the same points: the denominator
    // of the kernel-speedup trajectory in BENCH_mc.json (§Perf P5)
    for (name, kind) in [
        ("mc_qs_ref_n512", ArchKind::Qs),
        ("mc_qr_ref_n512", ArchKind::Qr),
        ("mc_cm_ref_n512", ArchKind::Cm),
    ] {
        let mut p = qs_params(512.0, 0.107);
        if kind == ArchKind::Qr {
            p[pvec::QR_IDX_SIGMA_C] = 0.08;
            p[pvec::QR_IDX_V_C] = 1.0;
        }
        if kind == ArchKind::Cm {
            p[pvec::CM_IDX_SIGMA_D] = 0.107;
            p[pvec::CM_IDX_W_H] = 1.0;
            p[pvec::CM_IDX_V_C] = 0.2;
        }
        let trials = 256;
        let mut seed = 0u64;
        suite.bench(name, trials as f64, || {
            seed += 1;
            black_box(mc::reference::simulate(kind, &p, trials, seed, InputDist::Uniform));
        });
    }

    // single-point wall-clock: a lone default-sized 512-row point, the
    // pareto --validate / figure shape that used to pin one core. The
    // chunked variant goes through the real scheduler (per-chunk jobs
    // over the default pool) vs the frozen serial path.
    {
        let p = qs_params(512.0, 0.107);
        let trials = 2048;
        suite.bench("mc_single_point_serial_n512", trials as f64, || {
            black_box(mc::measure(&mc::reference::simulate(
                ArchKind::Qs,
                &p,
                trials,
                11,
                InputDist::Uniform,
            )));
        });
        let point = SweepPoint::new("solo", ArchKind::Qs, p).with_trials(trials).with_seed(11);
        suite.bench("mc_single_point_chunked_n512", trials as f64, || {
            black_box(run_sweep(vec![point.clone()], Backend::Native, SweepOptions::default()));
        });
    }

    // ---- coordinator sweep throughput (Fig. 9a-shaped workload) -------
    {
        let points: Vec<SweepPoint> = (0..16)
            .map(|i| {
                SweepPoint::new(format!("b{i}"), ArchKind::Qs, qs_params(128.0, 0.1))
                    .with_trials(512)
                    .with_seed(i)
            })
            .collect();
        suite.bench("sweep_16pts_512trials_native", 16.0, || {
            black_box(run_sweep(
                points.clone(),
                Backend::Native,
                SweepOptions {
                    workers: 8,
                    verbose: false,
                },
            ));
        });
    }

    // ---- engine result cache: warm-run latency of the same workload ----
    {
        let dir = std::env::temp_dir().join("imclim-bench-engine-cache");
        let _ = std::fs::remove_dir_all(&dir);
        let engine = Engine::new(
            Backend::Native,
            SweepOptions {
                workers: 8,
                verbose: false,
            },
        )
        .with_cache(dir);
        let points: Vec<SweepPoint> = (0..16)
            .map(|i| {
                SweepPoint::new(format!("c{i}"), ArchKind::Qs, qs_params(128.0, 0.1))
                    .with_trials(512)
                    .with_seed(i)
            })
            .collect();
        black_box(engine.run(points.clone())); // cold run populates the cache
        suite.bench("engine_cached_sweep_16pts", 16.0, || {
            black_box(engine.run(points.clone()));
        });
    }

    // ---- figure/table regeneration (one bench per paper exhibit) ------
    let ctx = || {
        let mut c = FigCtx::native(std::env::temp_dir().join("imclim-bench"));
        c.trials = 512;
        c.verbose = false;
        // figure benches measure the cold compute path, not cache hits
        c.cache = false;
        c
    };
    for name in [
        "fig2", "fig4a", "fig4b", "fig9a", "fig9b", "fig10a", "fig10b",
        "fig11a", "fig11b", "fig12", "fig13", "table1", "table2", "table3",
    ] {
        let c = ctx();
        suite.bench(&format!("figure_{name}"), 1.0, || {
            // silence the driver's stdout noise by discarding summaries
            let s = figures::run(name, &c).unwrap();
            black_box(s);
        });
    }

    // ---- design-space optimizer (opt_*: emitted to BENCH_opt.json) ----
    {
        let (w, x) = figures::uniform_stats();
        let domain = Domain {
            archs: vec![ArchChoice::Qs, ArchChoice::Qr, ArchChoice::Cm],
            nodes: vec![TechNode::n65(), TechNode::n22()],
            vwls: vec![0.6, 0.65, 0.7, 0.75, 0.8],
            cos: vec![0.5, 1.0, 3.0, 9.0],
            ns: vec![32, 64, 128, 256, 512],
            bxs: vec![4, 6, 8],
            bws: vec![4, 6, 8],
            b_adcs: vec![2, 4, 6, 8, 10, 12],
            banks: vec![1],
        }
        .normalized()
        .unwrap();
        let candidates = domain.point_count() as f64;
        suite.bench("opt_frontier_extract", candidates, || {
            black_box(frontier(&domain, 1, &w, &x));
        });
        suite.bench("opt_frontier_extract_4shards", candidates, || {
            black_box(frontier(&domain, 4, &w, &x));
        });
        suite.bench("opt_min_energy_constrained", candidates, || {
            black_box(optimize(
                &domain,
                Objective::MinEnergy,
                &Constraints {
                    snr_t_min_db: Some(18.0),
                    ..Constraints::default()
                },
                &w,
                &x,
            ));
        });

        // area objective + banked families: the four-objective frontier
        // over a banks axis, and the min-area constrained search
        let banked_domain = Domain {
            banks: vec![1, 2, 4],
            ..domain.clone()
        }
        .normalized()
        .unwrap();
        let banked_candidates = banked_domain.point_count() as f64;
        suite.bench("opt_area_frontier_banked", banked_candidates, || {
            black_box(frontier(&banked_domain, 1, &w, &x));
        });
        suite.bench("opt_area_min_area_constrained", banked_candidates, || {
            black_box(optimize(
                &banked_domain,
                Objective::MinArea,
                &Constraints {
                    snr_t_min_db: Some(15.0),
                    ..Constraints::default()
                },
                &w,
                &x,
            ));
        });
    }

    // ---- DNN substrate -------------------------------------------------
    {
        use imclim::dnn::*;
        let ds = Dataset::generate(&DatasetConfig {
            train: 512,
            test: 256,
            ..Default::default()
        });
        let mut mlp = Mlp::new(&[64, 128, 64, 10], 7);
        mlp.train(
            &ds,
            &TrainConfig {
                epochs: 2,
                ..Default::default()
            },
        );
        let mut rng = imclim::util::rng::Pcg64::new(3);
        suite.bench("dnn_noisy_forward_256", 256.0, || {
            for i in 0..256 {
                let (x, _) = ds.test_sample(i);
                black_box(mlp.forward_noisy(x, &[0.5, 0.5, 0.5], &mut rng));
            }
        });
        suite.bench("dnn_train_epoch", ds.train_len() as f64, || {
            let mut m = mlp.clone();
            black_box(m.train(
                &ds,
                &TrainConfig {
                    epochs: 1,
                    ..Default::default()
                },
            ));
        });
    }

    // ---- PJRT path (end-to-end executor throughput) --------------------
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.json").exists() {
        let service = PjrtService::spawn(artifacts, 4);
        let handle = service.handle();
        // warm the compile caches
        let _ = handle.arch_shape("qs_arch");
        let _ = handle.arch_shape("qs_arch_small");

        for (bench, artifact, trials) in [
            ("pjrt_qs_small_batch", "_small", 16usize),
            ("pjrt_qs_full_batch", "", 64usize),
        ] {
            let h = handle.clone();
            let arch = QsArch::new(QsModel::new(TechNode::n65(), 0.8));
            let (w, x) = figures::uniform_stats();
            let op = OpPoint::new(48, 6, 6, 8);
            let point = SweepPoint::new("bench", ArchKind::Qs, arch.pjrt_params(&op, &w, &x))
                .with_trials(trials)
                .with_seed(5);
            let backend = Backend::Pjrt {
                handle: h,
                suffix: artifact,
            };
            suite.bench(bench, trials as f64, || {
                black_box(imclim::coordinator::run_point(&point, &backend).unwrap());
            });
        }

        // a full sweep through PJRT: 4 points x 128 trials on the small
        // artifact — the end-to-end coordinator+executor pipeline.
        let points: Vec<SweepPoint> = (0..4)
            .map(|i| {
                SweepPoint::new(format!("p{i}"), ArchKind::Qs, qs_params(48.0, 0.1))
                    .with_trials(128)
                    .with_seed(i)
            })
            .collect();
        let backend = Backend::Pjrt {
            handle: handle.clone(),
            suffix: "_small",
        };
        suite.bench("pjrt_sweep_4pts_128trials", 512.0, || {
            black_box(run_sweep(
                points.clone(),
                backend.clone(),
                SweepOptions {
                    workers: 4,
                    verbose: false,
                },
            ));
        });
    } else {
        eprintln!("(pjrt benches skipped: run `make artifacts`)");
    }

    // Persist the optimizer hot-path numbers so successive PRs get a
    // perf trajectory: BENCH_opt.json ($BENCH_OPT_JSON overrides the
    // path) holds one record per opt_* bench that ran this invocation.
    let opt_reports: Vec<Json> = suite
        .reports
        .iter()
        .filter(|r| r.name.starts_with("opt_"))
        .map(|r| {
            obj(vec![
                ("name", s(&r.name)),
                ("iters", num(r.iters as f64)),
                ("median_ns", num(r.median.as_nanos() as f64)),
                ("mad_ns", num(r.mad.as_nanos() as f64)),
                ("mean_ns", num(r.mean.as_nanos() as f64)),
                ("items_per_sec", num(r.items_per_sec())),
            ])
        })
        .collect();
    if !opt_reports.is_empty() {
        let path = std::env::var_os("BENCH_OPT_JSON")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| std::path::PathBuf::from("BENCH_opt.json"));
        let doc = obj(vec![
            ("suite", s("opt")),
            ("benches", arr(opt_reports)),
        ]);
        match std::fs::write(&path, doc.to_string()) {
            Ok(()) => println!("opt bench records -> {}", path.display()),
            Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
        }
    }

    // Monte-Carlo kernel trajectory: BENCH_mc.json ($BENCH_MC_JSON
    // overrides the path) holds every mc_* bench plus derived speedups
    // (batched kernels vs the frozen mc::reference path, chunked
    // scheduler vs serial single-point) and the adaptive-vs-fixed trial
    // counts. When $BENCH_MC_BASELINE names a *calibrated* baseline
    // file, any matching bench that lost >30% throughput fails the run
    // (the CI regression gate).
    let mc_reports: Vec<&imclim::bench::BenchReport> = suite
        .reports
        .iter()
        .filter(|r| r.name.starts_with("mc_"))
        .collect();
    if !mc_reports.is_empty() {
        // read the baseline *before* the default output path overwrites it
        let baseline = std::env::var_os("BENCH_MC_BASELINE").map(|p| {
            (
                std::path::PathBuf::from(&p),
                std::fs::read_to_string(&p).ok().and_then(|t| Json::parse(&t).ok()),
            )
        });

        let median_secs = |name: &str| -> Option<f64> {
            suite
                .reports
                .iter()
                .find(|r| r.name == name)
                .map(|r| r.median.as_secs_f64())
        };
        let mut derived: Vec<(&str, Json)> = Vec::new();
        for (label, new_name, ref_name) in [
            ("qs_kernel_speedup", "mc_qs_n512", "mc_qs_ref_n512"),
            ("qr_kernel_speedup", "mc_qr_n512", "mc_qr_ref_n512"),
            ("cm_kernel_speedup", "mc_cm_n512", "mc_cm_ref_n512"),
            (
                "single_point_speedup",
                "mc_single_point_chunked_n512",
                "mc_single_point_serial_n512",
            ),
        ] {
            if let (Some(new), Some(old)) = (median_secs(new_name), median_secs(ref_name)) {
                derived.push((label, num(old / new)));
            }
        }
        derived.push(("qs_kernel_speedup_floor", num(1.3)));
        derived.push(("single_point_speedup_floor", num(2.0)));

        // adaptive-precision economy at the 512-row reference point:
        // trials the stopping rule spends at 0.5 dB vs the fixed default
        {
            let p = qs_params(512.0, 0.107);
            let run = mc::simulate_adaptive(
                ArchKind::Qs,
                &p,
                0.5,
                11,
                InputDist::Uniform,
                mc::ADAPTIVE_MAX_TRIALS,
            );
            derived.push(("adaptive_trials_at_0p5db", num(run.measured.trials as f64)));
            derived.push(("adaptive_half_width_db", num(run.half_width_db)));
            derived.push(("adaptive_converged", Json::Bool(run.converged)));
            derived.push(("fixed_default_trials", num(2048.0)));
        }

        let bench_rows: Vec<Json> = mc_reports
            .iter()
            .map(|r| {
                obj(vec![
                    ("name", s(&r.name)),
                    ("iters", num(r.iters as f64)),
                    ("median_ns", num(r.median.as_nanos() as f64)),
                    ("mad_ns", num(r.mad.as_nanos() as f64)),
                    ("mean_ns", num(r.mean.as_nanos() as f64)),
                    ("items_per_sec", num(r.items_per_sec())),
                ])
            })
            .collect();
        let doc = obj(vec![
            ("suite", s("mc")),
            // a measured run is a valid future baseline; the committed
            // bootstrap file carries calibrated=false until CI numbers
            // replace its placeholders
            ("calibrated", Json::Bool(true)),
            ("benches", arr(bench_rows)),
            ("derived", obj(derived)),
        ]);
        let path = std::env::var_os("BENCH_MC_JSON")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| std::path::PathBuf::from("BENCH_mc.json"));
        match std::fs::write(&path, doc.to_string()) {
            Ok(()) => println!("mc bench records -> {}", path.display()),
            Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
        }

        match baseline {
            None => {}
            Some((bp, None)) => {
                eprintln!("warning: unreadable mc baseline {}", bp.display());
            }
            Some((bp, Some(base))) => {
                if base.get("calibrated") != Some(&Json::Bool(true)) {
                    println!(
                        "mc baseline {} not calibrated; regression gate skipped",
                        bp.display()
                    );
                } else {
                    let mut failed = false;
                    for b in base.get("benches").and_then(Json::as_arr).unwrap_or(&[]) {
                        let (Some(name), Some(base_ips)) = (
                            b.get("name").and_then(Json::as_str),
                            b.get("items_per_sec").and_then(Json::as_f64),
                        ) else {
                            continue;
                        };
                        if base_ips <= 0.0 {
                            continue;
                        }
                        let Some(r) = suite.reports.iter().find(|r| r.name == name) else {
                            continue;
                        };
                        let ips = r.items_per_sec();
                        if ips < 0.7 * base_ips {
                            eprintln!(
                                "PERF REGRESSION {name}: {ips:.1} items/s is {:.0}% below \
                                 baseline {base_ips:.1}",
                                (1.0 - ips / base_ips) * 100.0
                            );
                            failed = true;
                        }
                    }
                    if failed {
                        eprintln!("mc regression gate failed (>30% throughput loss)");
                        std::process::exit(1);
                    }
                    println!("mc regression gate passed vs {}", bp.display());
                }
            }
        }
    }

    println!("\n{} benches complete", suite.reports.len());
}
