//! `cargo bench` — throughput/latency benchmarks for every paper
//! table/figure regeneration plus the hot paths under them.
//!
//! Filter by substring: `cargo bench -- fig9` or `cargo bench -- mc_`.
//! Uses the in-repo harness (rust/src/bench). PJRT benches require
//! `make artifacts` and are skipped otherwise.

use std::time::Duration;

use imclim::arch::{pvec, ImcArch, OpPoint, QsArch};
use imclim::bench::{black_box, BenchConfig, Suite};
use imclim::compute::qs::QsModel;
use imclim::coordinator::{run_sweep, Backend, PjrtService, SweepOptions, SweepPoint};
use imclim::engine::Engine;
use imclim::figures::{self, FigCtx};
use imclim::mc::{simulate, ArchKind, InputDist};
use imclim::opt::{frontier, optimize, ArchChoice, Constraints, Domain, Objective};
use imclim::tech::TechNode;
use imclim::util::json::{arr, num, obj, s, Json};

fn qs_params(n: f64, sigma_d: f64) -> [f64; pvec::P] {
    let mut p = [0.0; pvec::P];
    p[pvec::IDX_N_ACTIVE] = n;
    p[pvec::IDX_BX] = 6.0;
    p[pvec::IDX_BW] = 6.0;
    p[pvec::IDX_B_ADC] = 8.0;
    p[pvec::QS_IDX_SIGMA_D] = sigma_d;
    p[pvec::QS_IDX_K_H] = 55.0;
    p[pvec::QS_IDX_V_C] = 55.0;
    p
}

fn main() {
    let mut suite = Suite::from_args(BenchConfig {
        warmup: Duration::from_millis(300),
        budget: Duration::from_secs(3),
        min_iters: 3,
        max_iters: 10_000,
    });

    // ---- L3 hot paths: native Monte-Carlo trial throughput ------------
    for (name, kind) in [
        ("mc_qs_n512", ArchKind::Qs),
        ("mc_qr_n512", ArchKind::Qr),
        ("mc_cm_n512", ArchKind::Cm),
    ] {
        let mut p = qs_params(512.0, 0.107);
        if kind == ArchKind::Qr {
            p[pvec::QR_IDX_SIGMA_C] = 0.08;
            p[pvec::QR_IDX_V_C] = 1.0;
        }
        if kind == ArchKind::Cm {
            p[pvec::CM_IDX_SIGMA_D] = 0.107;
            p[pvec::CM_IDX_W_H] = 1.0;
            p[pvec::CM_IDX_V_C] = 0.2;
        }
        let trials = 256;
        let mut seed = 0u64;
        suite.bench(name, trials as f64, || {
            seed += 1;
            black_box(simulate(kind, &p, trials, seed, InputDist::Uniform));
        });
    }

    // correlated-mismatch ablation path
    {
        let mut p = qs_params(512.0, 0.107);
        p[pvec::QS_IDX_MODE] = 1.0;
        suite.bench("mc_qs_n512_correlated", 256.0, || {
            black_box(simulate(ArchKind::Qs, &p, 256, 7, InputDist::Uniform));
        });
    }

    // ---- coordinator sweep throughput (Fig. 9a-shaped workload) -------
    {
        let points: Vec<SweepPoint> = (0..16)
            .map(|i| {
                SweepPoint::new(format!("b{i}"), ArchKind::Qs, qs_params(128.0, 0.1))
                    .with_trials(512)
                    .with_seed(i)
            })
            .collect();
        suite.bench("sweep_16pts_512trials_native", 16.0, || {
            black_box(run_sweep(
                points.clone(),
                Backend::Native,
                SweepOptions {
                    workers: 8,
                    verbose: false,
                },
            ));
        });
    }

    // ---- engine result cache: warm-run latency of the same workload ----
    {
        let dir = std::env::temp_dir().join("imclim-bench-engine-cache");
        let _ = std::fs::remove_dir_all(&dir);
        let engine = Engine::new(
            Backend::Native,
            SweepOptions {
                workers: 8,
                verbose: false,
            },
        )
        .with_cache(dir);
        let points: Vec<SweepPoint> = (0..16)
            .map(|i| {
                SweepPoint::new(format!("c{i}"), ArchKind::Qs, qs_params(128.0, 0.1))
                    .with_trials(512)
                    .with_seed(i)
            })
            .collect();
        black_box(engine.run(points.clone())); // cold run populates the cache
        suite.bench("engine_cached_sweep_16pts", 16.0, || {
            black_box(engine.run(points.clone()));
        });
    }

    // ---- figure/table regeneration (one bench per paper exhibit) ------
    let ctx = || {
        let mut c = FigCtx::native(std::env::temp_dir().join("imclim-bench"));
        c.trials = 512;
        c.verbose = false;
        // figure benches measure the cold compute path, not cache hits
        c.cache = false;
        c
    };
    for name in [
        "fig2", "fig4a", "fig4b", "fig9a", "fig9b", "fig10a", "fig10b",
        "fig11a", "fig11b", "fig12", "fig13", "table1", "table2", "table3",
    ] {
        let c = ctx();
        suite.bench(&format!("figure_{name}"), 1.0, || {
            // silence the driver's stdout noise by discarding summaries
            let s = figures::run(name, &c).unwrap();
            black_box(s);
        });
    }

    // ---- design-space optimizer (opt_*: emitted to BENCH_opt.json) ----
    {
        let (w, x) = figures::uniform_stats();
        let domain = Domain {
            archs: vec![ArchChoice::Qs, ArchChoice::Qr, ArchChoice::Cm],
            nodes: vec![TechNode::n65(), TechNode::n22()],
            vwls: vec![0.6, 0.65, 0.7, 0.75, 0.8],
            cos: vec![0.5, 1.0, 3.0, 9.0],
            ns: vec![32, 64, 128, 256, 512],
            bxs: vec![4, 6, 8],
            bws: vec![4, 6, 8],
            b_adcs: vec![2, 4, 6, 8, 10, 12],
            banks: vec![1],
        }
        .normalized()
        .unwrap();
        let candidates = domain.point_count() as f64;
        suite.bench("opt_frontier_extract", candidates, || {
            black_box(frontier(&domain, 1, &w, &x));
        });
        suite.bench("opt_frontier_extract_4shards", candidates, || {
            black_box(frontier(&domain, 4, &w, &x));
        });
        suite.bench("opt_min_energy_constrained", candidates, || {
            black_box(optimize(
                &domain,
                Objective::MinEnergy,
                &Constraints {
                    snr_t_min_db: Some(18.0),
                    ..Constraints::default()
                },
                &w,
                &x,
            ));
        });

        // area objective + banked families: the four-objective frontier
        // over a banks axis, and the min-area constrained search
        let banked_domain = Domain {
            banks: vec![1, 2, 4],
            ..domain.clone()
        }
        .normalized()
        .unwrap();
        let banked_candidates = banked_domain.point_count() as f64;
        suite.bench("opt_area_frontier_banked", banked_candidates, || {
            black_box(frontier(&banked_domain, 1, &w, &x));
        });
        suite.bench("opt_area_min_area_constrained", banked_candidates, || {
            black_box(optimize(
                &banked_domain,
                Objective::MinArea,
                &Constraints {
                    snr_t_min_db: Some(15.0),
                    ..Constraints::default()
                },
                &w,
                &x,
            ));
        });
    }

    // ---- DNN substrate -------------------------------------------------
    {
        use imclim::dnn::*;
        let ds = Dataset::generate(&DatasetConfig {
            train: 512,
            test: 256,
            ..Default::default()
        });
        let mut mlp = Mlp::new(&[64, 128, 64, 10], 7);
        mlp.train(
            &ds,
            &TrainConfig {
                epochs: 2,
                ..Default::default()
            },
        );
        let mut rng = imclim::util::rng::Pcg64::new(3);
        suite.bench("dnn_noisy_forward_256", 256.0, || {
            for i in 0..256 {
                let (x, _) = ds.test_sample(i);
                black_box(mlp.forward_noisy(x, &[0.5, 0.5, 0.5], &mut rng));
            }
        });
        suite.bench("dnn_train_epoch", ds.train_len() as f64, || {
            let mut m = mlp.clone();
            black_box(m.train(
                &ds,
                &TrainConfig {
                    epochs: 1,
                    ..Default::default()
                },
            ));
        });
    }

    // ---- PJRT path (end-to-end executor throughput) --------------------
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.json").exists() {
        let service = PjrtService::spawn(artifacts, 4);
        let handle = service.handle();
        // warm the compile caches
        let _ = handle.arch_shape("qs_arch");
        let _ = handle.arch_shape("qs_arch_small");

        for (bench, artifact, trials) in [
            ("pjrt_qs_small_batch", "_small", 16usize),
            ("pjrt_qs_full_batch", "", 64usize),
        ] {
            let h = handle.clone();
            let arch = QsArch::new(QsModel::new(TechNode::n65(), 0.8));
            let (w, x) = figures::uniform_stats();
            let op = OpPoint::new(48, 6, 6, 8);
            let point = SweepPoint::new("bench", ArchKind::Qs, arch.pjrt_params(&op, &w, &x))
                .with_trials(trials)
                .with_seed(5);
            let backend = Backend::Pjrt {
                handle: h,
                suffix: artifact,
            };
            suite.bench(bench, trials as f64, || {
                black_box(imclim::coordinator::run_point(&point, &backend).unwrap());
            });
        }

        // a full sweep through PJRT: 4 points x 128 trials on the small
        // artifact — the end-to-end coordinator+executor pipeline.
        let points: Vec<SweepPoint> = (0..4)
            .map(|i| {
                SweepPoint::new(format!("p{i}"), ArchKind::Qs, qs_params(48.0, 0.1))
                    .with_trials(128)
                    .with_seed(i)
            })
            .collect();
        let backend = Backend::Pjrt {
            handle: handle.clone(),
            suffix: "_small",
        };
        suite.bench("pjrt_sweep_4pts_128trials", 512.0, || {
            black_box(run_sweep(
                points.clone(),
                backend.clone(),
                SweepOptions {
                    workers: 4,
                    verbose: false,
                },
            ));
        });
    } else {
        eprintln!("(pjrt benches skipped: run `make artifacts`)");
    }

    // Persist the optimizer hot-path numbers so successive PRs get a
    // perf trajectory: BENCH_opt.json ($BENCH_OPT_JSON overrides the
    // path) holds one record per opt_* bench that ran this invocation.
    let opt_reports: Vec<Json> = suite
        .reports
        .iter()
        .filter(|r| r.name.starts_with("opt_"))
        .map(|r| {
            obj(vec![
                ("name", s(&r.name)),
                ("iters", num(r.iters as f64)),
                ("median_ns", num(r.median.as_nanos() as f64)),
                ("mad_ns", num(r.mad.as_nanos() as f64)),
                ("mean_ns", num(r.mean.as_nanos() as f64)),
                ("items_per_sec", num(r.items_per_sec())),
            ])
        })
        .collect();
    if !opt_reports.is_empty() {
        let path = std::env::var_os("BENCH_OPT_JSON")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| std::path::PathBuf::from("BENCH_opt.json"));
        let doc = obj(vec![
            ("suite", s("opt")),
            ("benches", arr(opt_reports)),
        ]);
        match std::fs::write(&path, doc.to_string()) {
            Ok(()) => println!("opt bench records -> {}", path.display()),
            Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
        }
    }

    println!("\n{} benches complete", suite.reports.len());
}
