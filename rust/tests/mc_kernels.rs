//! Differential tests pinning the batched chunk kernels (`mc::kernels`)
//! against the frozen pre-batching scalar path (`mc::reference`), plus
//! the intra-point scheduler's byte-determinism contract.
//!
//! Equality tiers (see the `mc::kernels` module docs):
//!  * QS and CM preserve the reference's RNG draw order *and* its exact
//!    float operations (every hoisted scaling is a power of two, so
//!    multiply-by-reciprocal equals the reference's divide bit-for-bit)
//!    — one chunk at the same seed is bit-identical to the reference.
//!  * QR rewrites the masked per-row accumulation into 4 independent
//!    lanes: same draws, different summation association. It is pinned
//!    per-trial within FP-association noise and at ensemble level
//!    within Monte-Carlo tolerance.

use imclim::arch::pvec;
use imclim::coordinator::{run_sweep, Backend, SweepOptions, SweepPoint};
use imclim::mc::{self, ArchKind, InputDist};

/// QS operating point with every noise term live (mismatch, pulse
/// jitter, retention droop, comparator offset, finite clip, real ADC).
fn qs_params(n: usize, correlated: bool) -> [f64; pvec::P] {
    let mut p = [0.0; pvec::P];
    p[pvec::IDX_N_ACTIVE] = n as f64;
    p[pvec::IDX_BX] = 6.0;
    p[pvec::IDX_BW] = 6.0;
    p[pvec::IDX_B_ADC] = 8.0;
    p[pvec::QS_IDX_SIGMA_D] = 0.107;
    p[pvec::QS_IDX_SIGMA_T] = 0.05;
    p[pvec::QS_IDX_T_RF] = 0.01;
    p[pvec::QS_IDX_SIGMA_THETA] = 0.2;
    p[pvec::QS_IDX_K_H] = 60.0;
    p[pvec::QS_IDX_V_C] = 60.0;
    p[pvec::QS_IDX_MODE] = if correlated { 1.0 } else { 0.0 };
    p
}

fn qr_params(n: usize) -> [f64; pvec::P] {
    let mut p = [0.0; pvec::P];
    p[pvec::IDX_N_ACTIVE] = n as f64;
    p[pvec::IDX_BX] = 6.0;
    p[pvec::IDX_BW] = 7.0;
    p[pvec::IDX_B_ADC] = 8.0;
    p[pvec::QR_IDX_SIGMA_C] = 0.05;
    p[pvec::QR_IDX_INJ_A] = 0.01;
    p[pvec::QR_IDX_INJ_B] = 0.02;
    p[pvec::QR_IDX_SIGMA_THETA] = 0.003;
    p[pvec::QR_IDX_V_C] = 1.0;
    p[pvec::QR_IDX_V_LO] = -0.1;
    p
}

fn cm_params(n: usize) -> [f64; pvec::P] {
    let mut p = [0.0; pvec::P];
    p[pvec::IDX_N_ACTIVE] = n as f64;
    p[pvec::IDX_BX] = 6.0;
    p[pvec::IDX_BW] = 6.0;
    p[pvec::IDX_B_ADC] = 8.0;
    p[pvec::CM_IDX_SIGMA_D] = 0.1;
    p[pvec::CM_IDX_W_H] = 1.1;
    p[pvec::CM_IDX_SIGMA_C] = 0.03;
    p[pvec::CM_IDX_INJ_A] = 0.01;
    p[pvec::CM_IDX_INJ_B] = 0.02;
    p[pvec::CM_IDX_SIGMA_THETA] = 0.002;
    p[pvec::CM_IDX_V_C] = 0.6;
    p
}

/// One chunk at one seed is one RNG stream in both paths, so the
/// kernels' output must match the reference bit-for-bit where the float
/// operations are preserved (QS, CM).
fn assert_bitwise_chunk(kind: ArchKind, p: &[f64; pvec::P], what: &str) {
    let trials = 192; // < CHUNK_TRIALS: a single chunk in both paths
    for seed in [1u64, 0x5EED, 0xDEAD_BEEF] {
        for dist in [
            InputDist::Uniform,
            InputDist::ClippedGaussian { sx: 0.4, sw: 0.4 },
        ] {
            let new = mc::simulate_chunk(kind, p, trials, seed, dist);
            let old = mc::reference::simulate(kind, p, trials, seed, dist);
            assert_eq!(new.y_ideal, old.y_ideal, "{what} y_ideal seed={seed}");
            assert_eq!(new.y_fx, old.y_fx, "{what} y_fx seed={seed}");
            assert_eq!(new.y_a, old.y_a, "{what} y_a seed={seed}");
            assert_eq!(new.y_hat, old.y_hat, "{what} y_hat seed={seed}");
        }
    }
}

#[test]
fn qs_kernel_is_bitwise_identical_to_reference_within_one_chunk() {
    assert_bitwise_chunk(ArchKind::Qs, &qs_params(48, false), "qs");
    // odd N exercises the tail of every vectorized row loop
    assert_bitwise_chunk(ArchKind::Qs, &qs_params(37, false), "qs/odd-n");
}

#[test]
fn qs_correlated_kernel_is_bitwise_identical_to_reference() {
    assert_bitwise_chunk(ArchKind::Qs, &qs_params(48, true), "qs-corr");
}

#[test]
fn cm_kernel_is_bitwise_identical_to_reference_within_one_chunk() {
    assert_bitwise_chunk(ArchKind::Cm, &cm_params(64), "cm");
    assert_bitwise_chunk(ArchKind::Cm, &cm_params(53), "cm/odd-n");
}

#[test]
fn banked_kernel_is_bitwise_identical_to_reference_within_one_chunk() {
    // the banked decomposition (per-bank sub-ensembles at bank_seed)
    // is shared code shape but independent arithmetic in the two paths
    let mut p = qs_params(64, false);
    p[pvec::IDX_BANKS] = 4.0;
    assert_bitwise_chunk(ArchKind::Qs, &p, "qs/banks=4");
}

#[test]
fn qr_kernel_tracks_reference_within_fp_association_noise() {
    // QR sums the masked rows in 4 lanes; same draws, different float
    // association. Per-trial agreement is at rounding level, far below
    // any physical noise term.
    let p = qr_params(67); // odd N: remainder lane exercised
    let trials = 192;
    for seed in [3u64, 0x5EED] {
        let new = mc::simulate_chunk(ArchKind::Qr, &p, trials, seed, InputDist::Uniform);
        let old = mc::reference::simulate(ArchKind::Qr, &p, trials, seed, InputDist::Uniform);
        assert_eq!(new.y_ideal, old.y_ideal, "same draws, same accumulation");
        assert_eq!(new.y_fx, old.y_fx);
        for i in 0..trials {
            let scale = old.y_a[i].abs() + 1.0;
            assert!(
                (new.y_a[i] - old.y_a[i]).abs() <= 1e-9 * scale,
                "trial {i}: y_a {} vs {}",
                new.y_a[i],
                old.y_a[i]
            );
            assert!(
                (new.y_hat[i] - old.y_hat[i]).abs() <= 1e-9 * (old.y_hat[i].abs() + 1.0),
                "trial {i}: y_hat {} vs {}",
                new.y_hat[i],
                old.y_hat[i]
            );
        }
    }
}

#[test]
fn chunked_ensembles_match_reference_statistics() {
    // Whole-ensemble cross-check: the production path draws per-chunk
    // streams (chunk_seed) while the reference draws one stream, so the
    // two 4096-trial ensembles are independent samples of the same
    // physics — their measured SNRs agree within MC ensemble error
    // (~0.4 dB at 4k trials; tolerance doubled for headroom).
    let cases: [(ArchKind, [f64; pvec::P], &str); 4] = [
        (ArchKind::Qs, qs_params(128, false), "qs"),
        (ArchKind::Qs, qs_params(128, true), "qs-corr"),
        (ArchKind::Qr, qr_params(128), "qr"),
        (ArchKind::Cm, cm_params(128), "cm"),
    ];
    for (kind, p, what) in cases {
        let trials = 4096;
        let new = mc::measure(&mc::simulate(kind, &p, trials, 0xD1FF, InputDist::Uniform));
        let old = mc::measure(&mc::reference::simulate(
            kind,
            &p,
            trials,
            0xD1FF,
            InputDist::Uniform,
        ));
        for (a, b, name) in [
            (new.snr_a_total_db, old.snr_a_total_db, "snr_a_total_db"),
            (new.snr_t_db, old.snr_t_db, "snr_t_db"),
            (new.sqnr_qiy_db, old.sqnr_qiy_db, "sqnr_qiy_db"),
        ] {
            assert!(
                (a - b).abs() < 0.8,
                "{what} {name}: {a:.3} dB vs {b:.3} dB"
            );
        }
        let ratio = new.sigma_eta_a2 / old.sigma_eta_a2;
        assert!((0.8..1.25).contains(&ratio), "{what} sigma_eta_a2 {ratio}");
    }
}

#[test]
fn mixed_grid_is_byte_deterministic_across_worker_counts() {
    // The scheduler fans multi-chunk points into per-chunk jobs; chunk
    // re-assembly in chunk order must make every measured field of
    // every point bit-identical no matter how many workers raced.
    let mk = || {
        vec![
            SweepPoint::new("qs/700", ArchKind::Qs, qs_params(64, false))
                .with_trials(700)
                .with_seed(11),
            SweepPoint::new("qr/1024", ArchKind::Qr, qr_params(96))
                .with_trials(1024)
                .with_seed(12),
            SweepPoint::new("cm/300", ArchKind::Cm, cm_params(48))
                .with_trials(300)
                .with_seed(13),
            SweepPoint::new("qs/128-single-chunk", ArchKind::Qs, qs_params(32, false))
                .with_trials(128)
                .with_seed(14),
        ]
    };
    let runs: Vec<_> = [1usize, 2, 8]
        .iter()
        .map(|&workers| {
            run_sweep(
                mk(),
                Backend::Native,
                SweepOptions {
                    workers,
                    verbose: false,
                },
            )
        })
        .collect();
    for run in &runs[1..] {
        for (a, b) in runs[0].iter().zip(run) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.measured.trials, b.measured.trials);
            for (x, y, name) in [
                (a.measured.sigma_yo2, b.measured.sigma_yo2, "sigma_yo2"),
                (a.measured.sigma_eta_a2, b.measured.sigma_eta_a2, "sigma_eta_a2"),
                (a.measured.sigma_qy2, b.measured.sigma_qy2, "sigma_qy2"),
                (a.measured.snr_a_total_db, b.measured.snr_a_total_db, "snr_a_total_db"),
                (a.measured.snr_t_db, b.measured.snr_t_db, "snr_t_db"),
            ] {
                assert_eq!(x.to_bits(), y.to_bits(), "{}: {name}", a.id);
            }
        }
    }
}
