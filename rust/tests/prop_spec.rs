//! Property-style tests for `SweepSpec`: k-way sharding is a partition
//! of the full grid (union == grid, pairwise disjoint, ids and values
//! unchanged), and grid-string parsing round-trips through formatting,
//! including degenerate ranges and error cases.

use std::collections::BTreeMap;

use imclim::engine::{parse_grid_f64, parse_grid_u32, parse_grid_usize, parse_shard, SweepSpec};

/// A deterministic multi-axis grid of the given shape.
fn spec(shape: &[usize]) -> SweepSpec {
    let mut s = SweepSpec::new("prop");
    for (a, &len) in shape.iter().enumerate() {
        let vals: Vec<usize> = (0..len).map(|v| v * (a + 2) + 1).collect();
        s = s.axis_usize(&format!("a{a}"), &vals);
    }
    s
}

#[test]
fn sharding_is_a_partition_for_many_shapes_and_counts() {
    let shapes = [
        vec![1],
        vec![5],
        vec![2, 3],
        vec![4, 1, 3],
        vec![2, 2, 2, 2],
        vec![7, 5],
    ];
    for shape in &shapes {
        let full = spec(shape).points();
        for k in 1..=7 {
            // union of all shards covers every global index exactly once
            let mut seen: BTreeMap<usize, String> = BTreeMap::new();
            for i in 0..k {
                let shard = spec(shape).shard(i, k).unwrap();
                let points = shard.points();
                assert_eq!(
                    points.len(),
                    shard.len(),
                    "len() consistent with points() for shard {i}/{k}"
                );
                for (j, p) in points.into_iter().enumerate() {
                    // point j of shard i is global point i + j*k
                    let global = i + j * k;
                    assert!(global < full.len(), "shard emits only grid points");
                    assert_eq!(p.id, full[global].id, "ids unchanged by sharding");
                    assert_eq!(
                        p.values, full[global].values,
                        "values unchanged by sharding"
                    );
                    let prev = seen.insert(global, p.id);
                    assert!(prev.is_none(), "point {global} claimed by two shards");
                }
            }
            assert_eq!(
                seen.len(),
                full.len(),
                "shards {k}-partition the {shape:?} grid"
            );
        }
    }
}

#[test]
fn shard_len_formula_matches_enumeration() {
    for total_shape in [vec![1], vec![3], vec![10], vec![3, 4], vec![13]] {
        let full_len = spec(&total_shape).points().len();
        for k in 1..=9 {
            let mut sum = 0;
            for i in 0..k {
                let s = spec(&total_shape).shard(i, k).unwrap();
                assert_eq!(s.len(), s.points().len());
                sum += s.len();
            }
            assert_eq!(sum, full_len, "shape {total_shape:?}, k={k}");
        }
    }
}

#[test]
fn shard_validation_errors() {
    let base = spec(&[4]);
    assert!(base.clone().shard(0, 0).is_err(), "zero shards");
    assert!(base.clone().shard(2, 2).is_err(), "index == count");
    assert!(
        base.clone().shard(0, 2).unwrap().shard(1, 2).is_err(),
        "re-sharding a shard"
    );
    assert!(parse_shard("2/4").is_ok());
    assert!(parse_shard("4/4").is_err());
    assert!(parse_shard("x/4").is_err());
    assert!(parse_shard("1:4").is_err());
    assert!(parse_shard("").is_err());
}

#[test]
fn grid_lists_roundtrip_through_formatting() {
    let usize_lists = [
        vec![1, 2, 3],
        vec![64, 128],
        vec![5],
        vec![2, 4, 6, 8, 100],
    ];
    for vals in &usize_lists {
        let joined = vals
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join(",");
        assert_eq!(&parse_grid_usize(&joined).unwrap(), vals, "{joined}");
    }
    let f64_lists = [vec![0.5, 0.75], vec![1.0, 2.5, 3.25], vec![0.625]];
    for vals in &f64_lists {
        let joined = vals
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join(",");
        assert_eq!(&parse_grid_f64(&joined).unwrap(), vals, "{joined}");
    }
}

#[test]
fn ranges_expand_inclusively_and_roundtrip() {
    let expanded = parse_grid_usize("4:16:4").unwrap();
    assert_eq!(expanded, vec![4, 8, 12, 16]);
    // re-formatting the expansion parses back to the same grid
    let rejoined = expanded
        .iter()
        .map(|v| v.to_string())
        .collect::<Vec<_>>()
        .join(",");
    assert_eq!(parse_grid_usize(&rejoined).unwrap(), expanded);
    // step that overshoots the upper bound stops at the last in-range value
    assert_eq!(parse_grid_usize("1:10:4").unwrap(), vec![1, 5, 9]);
    // float range hits its inclusive endpoint within epsilon
    let v = parse_grid_f64("0.6:0.9:0.1").unwrap();
    assert_eq!(v.len(), 4);
    assert!((v[3] - 0.9).abs() < 1e-9);
}

#[test]
fn float_range_endpoints_are_deterministic() {
    // The endpoint rule: hi is included iff (hi-lo)/step is within 1e-9
    // *relative* tolerance of an integer, and when included the last
    // value is exactly the hi that was typed — never lo + k*step with
    // its accumulated representation error.
    let v = parse_grid_f64("0.55:0.9:0.05").unwrap();
    assert_eq!(v.len(), 8);
    assert_eq!(v[0].to_bits(), 0.55f64.to_bits());
    assert_eq!(v[7].to_bits(), 0.9f64.to_bits(), "snapped to the literal hi");
    let v = parse_grid_f64("0:0.3:0.1").unwrap();
    assert_eq!(v.len(), 4);
    assert_eq!(v[3].to_bits(), 0.3f64.to_bits());
    // interior values are lo + i*step (multiplication, no accumulation)
    assert_eq!(v[2].to_bits(), (0.1f64 * 2.0).to_bits());
    // absolute-epsilon would misjudge large-magnitude ranges; the
    // relative rule keeps the endpoint: (1000.3-1000)/0.1 = 3 + 8e-14
    let v = parse_grid_f64("1000:1000.3:0.1").unwrap();
    assert_eq!(v.len(), 4);
    assert_eq!(v[3].to_bits(), 1000.3f64.to_bits());
}

#[test]
fn float_range_non_dividing_steps_stop_in_range() {
    // a step that does not divide the span stops at the last in-range
    // value; the endpoint is excluded deterministically
    let v = parse_grid_f64("1:10:4").unwrap();
    assert_eq!(v, vec![1.0, 5.0, 9.0]);
    let v = parse_grid_f64("0:1:0.3").unwrap();
    assert_eq!(v.len(), 4);
    assert!(v[3] < 1.0, "endpoint excluded: {v:?}");
    assert_eq!(v[3].to_bits(), (0.3f64 * 3.0).to_bits());
    // just short of dividing (rel err ~3e-4 >> 1e-9): excluded
    let v = parse_grid_f64("0:2.999:1").unwrap();
    assert_eq!(v, vec![0.0, 1.0, 2.0]);
    // oversize float ranges still error out
    assert!(parse_grid_f64("0:1000000:0.1").is_err());
}

#[test]
fn degenerate_ranges() {
    assert_eq!(parse_grid_usize("7:7").unwrap(), vec![7]);
    assert_eq!(parse_grid_usize("7:7:3").unwrap(), vec![7]);
    assert_eq!(parse_grid_f64("2:2").unwrap(), vec![2.0]);
    assert_eq!(parse_grid_f64("2.5:2.5:0.5").unwrap(), vec![2.5]);
    assert_eq!(parse_grid_u32("0:0").unwrap(), vec![0]);
    // mixed lists and ranges compose in order
    assert_eq!(parse_grid_usize("9,1:3,7").unwrap(), vec![9, 1, 2, 3, 7]);
}

#[test]
fn error_cases_reject_cleanly() {
    assert!(parse_grid_usize("").is_err());
    assert!(parse_grid_usize(",,,").is_err());
    assert!(parse_grid_usize("5:2").is_err(), "descending");
    assert!(parse_grid_usize("1:5:0").is_err(), "zero step");
    assert!(parse_grid_f64("1:2:3:4").is_err(), "too many fields");
    assert!(parse_grid_f64("0.6:0.8").is_err(), "sub-unit step-less");
    assert!(parse_grid_f64("1:2:-1").is_err(), "negative step");
    assert!(parse_grid_u32("99999999999").is_err(), "u32 overflow");
    assert!(parse_grid_usize("abc").is_err());
}
