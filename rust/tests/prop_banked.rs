//! Property tests for the banked-architecture layer (Sec. VI /
//! conclusion 4), locking down the two contracts everything else leans
//! on:
//!
//! 1. `Banked::new(inner, 1)` is *bit-identical* to the bare inner
//!    architecture — noise, energy, delay, area, parameter vector and
//!    (therefore) result-cache keys — across randomized operating
//!    points of both QS and QR, so admitting banking into the sweep
//!    engine and optimizer cannot perturb a single pre-existing value.
//! 2. For banks >= 2 the banked noise decomposition is *exactly*
//!    `banks x` the per-bank one, and energy/delay/area decompose into
//!    per-bank replication plus the closed-form adder tree.

use imclim::arch::{pvec, AdcCriterion, Banked, ImcArch, OpPoint, QrArch, QsArch};
use imclim::compute::{qr::QrModel, qs::QsModel};
use imclim::coordinator::SweepPoint;
use imclim::engine::cache_key;
use imclim::mc::ArchKind;
use imclim::quant::SignalStats;
use imclim::tech::TechNode;
use imclim::util::rng::Pcg64;

fn stats() -> (SignalStats, SignalStats) {
    (
        SignalStats::uniform_signed(1.0),
        SignalStats::uniform_unsigned(1.0),
    )
}

/// Randomized operating points spanning both sides of the N_max cliff.
fn random_ops(rng: &mut Pcg64, count: usize) -> Vec<OpPoint> {
    (0..count)
        .map(|_| {
            OpPoint::new(
                8 + rng.below(600) as usize,
                2 + rng.below(7) as u32,
                2 + rng.below(7) as u32,
                2 + rng.below(11) as u32,
            )
        })
        .collect()
}

/// (bare architecture, identical twin, simulator kind).
type ArchPair = (Box<dyn ImcArch>, Box<dyn ImcArch>, ArchKind);

/// The two architecture families under test, as (bare, identical twin,
/// kind): QS across the V_WL range, QR across the C_o range. Both
/// models are `Copy`, so the twin is bit-identical to the bare one —
/// the twin gets consumed by the `Banked` wrapper under test.
fn arch_pool(rng: &mut Pcg64) -> Vec<ArchPair> {
    let v_wl = 0.55 + rng.uniform() * 0.35;
    let c_ff = 0.5 + rng.uniform() * 8.5;
    let qs = QsArch::new(QsModel::new(TechNode::n65(), v_wl));
    let qr = QrArch::new(QrModel::new(TechNode::n65(), c_ff));
    vec![
        (Box::new(qs), Box::new(qs), ArchKind::Qs),
        (Box::new(qr), Box::new(qr), ArchKind::Qr),
    ]
}

#[test]
fn one_bank_wrapper_is_bit_identical_to_the_bare_architecture() {
    let (w, x) = stats();
    let mut rng = Pcg64::new(0xBA2C);
    for round in 0..20 {
        for (bare, twin, kind) in arch_pool(&mut rng) {
            let wrapped = Banked::new(twin, 1);
            for op in random_ops(&mut rng, 8) {
                let a = bare.noise(&op, &w, &x);
                let b = wrapped.noise(&op, &w, &x);
                assert_eq!(a.sigma_yo2.to_bits(), b.sigma_yo2.to_bits(), "round {round}");
                assert_eq!(a.sigma_qiy2.to_bits(), b.sigma_qiy2.to_bits());
                assert_eq!(a.sigma_eta_h2.to_bits(), b.sigma_eta_h2.to_bits());
                assert_eq!(a.sigma_eta_e2.to_bits(), b.sigma_eta_e2.to_bits());
                for crit in [
                    AdcCriterion::Mpc,
                    AdcCriterion::Bgc,
                    AdcCriterion::Fixed(op.b_adc),
                ] {
                    let ea = bare.energy(&op, crit, &w, &x);
                    let eb = wrapped.energy(&op, crit, &w, &x);
                    assert_eq!(ea.analog.to_bits(), eb.analog.to_bits());
                    assert_eq!(ea.adc.to_bits(), eb.adc.to_bits());
                    assert_eq!(ea.misc.to_bits(), eb.misc.to_bits(), "no tree at 1 bank");
                }
                assert_eq!(bare.delay(&op).to_bits(), wrapped.delay(&op).to_bits());
                let aa = bare.area(&op);
                let ab = wrapped.area(&op);
                assert_eq!(aa.array_mm2.to_bits(), ab.array_mm2.to_bits());
                assert_eq!(aa.caps_mm2.to_bits(), ab.caps_mm2.to_bits());
                assert_eq!(aa.adc_mm2.to_bits(), ab.adc_mm2.to_bits());
                assert_eq!(aa.periphery_mm2.to_bits(), ab.periphery_mm2.to_bits());
                assert_eq!(bare.b_adc_min(&op, &w, &x), wrapped.b_adc_min(&op, &w, &x));
                assert_eq!(
                    bare.v_c_volts(&op, &w, &x).to_bits(),
                    wrapped.v_c_volts(&op, &w, &x).to_bits()
                );
                // the parameter vector is bit-identical, so the
                // result-cache key is unchanged: a banks=1 sweep row
                // aliases (correctly) with the pre-banking records
                let pa = bare.pjrt_params(&op, &w, &x);
                let pb = wrapped.pjrt_params(&op, &w, &x);
                assert_eq!(pa, pb);
                assert_eq!(pb[pvec::IDX_BANKS], 0.0, "legacy single-bank slot");
                let key_a = cache_key(
                    &SweepPoint::new("a", kind, pa).with_trials(64).with_seed(1),
                    "native@test",
                );
                let key_b = cache_key(
                    &SweepPoint::new("b-different-label", kind, pb)
                        .with_trials(64)
                        .with_seed(1),
                    "native@test",
                );
                assert_eq!(key_a, key_b, "banks=1 cache keys are unchanged");
            }
        }
    }
}

#[test]
fn banked_noise_is_exactly_banks_times_the_per_bank_decomposition() {
    let (w, x) = stats();
    let mut rng = Pcg64::new(0xBA2D);
    for _ in 0..15 {
        for &banks in &[2usize, 3, 4, 8] {
            for (bare, twin, _kind) in arch_pool(&mut rng) {
                let wrapped = Banked::new(twin, banks);
                for op in random_ops(&mut rng, 4) {
                    let bank_op = OpPoint {
                        n: op.n.div_ceil(banks),
                        banks: 1,
                        ..op
                    };
                    let per = bare.noise(&bank_op, &w, &x);
                    let tot = wrapped.noise(&op, &w, &x);
                    let k = banks as f64;
                    assert_eq!(tot.sigma_yo2.to_bits(), (per.sigma_yo2 * k).to_bits());
                    assert_eq!(tot.sigma_qiy2.to_bits(), (per.sigma_qiy2 * k).to_bits());
                    assert_eq!(
                        tot.sigma_eta_h2.to_bits(),
                        (per.sigma_eta_h2 * k).to_bits()
                    );
                    assert_eq!(
                        tot.sigma_eta_e2.to_bits(),
                        (per.sigma_eta_e2 * k).to_bits()
                    );
                    // every SNR ratio is bank-count-invariant (the
                    // escape mechanism: per-bank physics at total-N
                    // signal), up to the multiplication round-off
                    let d = (tot.snr_a_total_db() - per.snr_a_total_db()).abs();
                    assert!(d < 1e-9, "ratio preserved: {d}");
                }
            }
        }
    }
}

#[test]
fn banked_energy_delay_area_decompose_into_replication_plus_tree() {
    let (w, x) = stats();
    let mut rng = Pcg64::new(0xBA2E);
    let tech = TechNode::n65();
    for _ in 0..15 {
        for &banks in &[2usize, 4, 8] {
            for (bare, twin, _kind) in arch_pool(&mut rng) {
                let wrapped = Banked::new(twin, banks);
                for op in random_ops(&mut rng, 4) {
                    let bank_op = OpPoint {
                        n: op.n.div_ceil(banks),
                        banks: 1,
                        ..op
                    };
                    let per = bare.energy(&bank_op, AdcCriterion::Mpc, &w, &x);
                    let tot = wrapped.energy(&op, AdcCriterion::Mpc, &w, &x);
                    let k = banks as f64;
                    assert_eq!(tot.analog.to_bits(), (per.analog * k).to_bits());
                    assert_eq!(tot.adc.to_bits(), (per.adc * k).to_bits());
                    assert_eq!(
                        tot.misc.to_bits(),
                        (per.misc + (banks - 1) as f64 * tech.e_bank_add).to_bits()
                    );
                    let stages = (banks as f64).log2().ceil();
                    assert_eq!(
                        wrapped.delay(&op).to_bits(),
                        (bare.delay(&bank_op) + stages * tech.t_bank_add()).to_bits()
                    );
                    let pa = bare.area(&bank_op);
                    let ta = wrapped.area(&op);
                    assert_eq!(ta.array_mm2.to_bits(), (pa.array_mm2 * k).to_bits());
                    assert_eq!(ta.caps_mm2.to_bits(), (pa.caps_mm2 * k).to_bits());
                    assert_eq!(ta.adc_mm2.to_bits(), (pa.adc_mm2 * k).to_bits());
                    let tree = imclim::area::bank_adder_mm2(&tech, banks);
                    assert_eq!(
                        ta.periphery_mm2.to_bits(),
                        (pa.periphery_mm2 * k + tree).to_bits()
                    );
                }
            }
        }
    }
}

#[test]
fn banked_parameter_vectors_key_apart_from_single_bank() {
    // banks >= 2 changes the cache key (slot 15), and different bank
    // counts key apart from each other — banked results can never
    // alias single-bank records.
    let (w, x) = stats();
    let arch = QsArch::new(QsModel::new(TechNode::n65(), 0.8));
    let op = OpPoint::new(512, 6, 6, 8);
    let keys: Vec<String> = [1usize, 2, 4, 8]
        .iter()
        .map(|&banks| {
            let b = Banked::new(Box::new(arch), banks);
            cache_key(
                &SweepPoint::new("p", ArchKind::Qs, b.pjrt_params(&op, &w, &x))
                    .with_trials(128)
                    .with_seed(7),
                "native@test",
            )
        })
        .collect();
    for (i, a) in keys.iter().enumerate() {
        for (j, b) in keys.iter().enumerate() {
            if i != j {
                assert_ne!(a, b, "banks variants share a cache key");
            }
        }
    }
    // note banks=2 and banks=4 at n=512 have different per-bank N too,
    // but even same-bank-N variants differ through slot 15:
    let b2 = Banked::new(Box::new(arch), 2);
    let b4 = Banked::new(Box::new(arch), 4);
    let p2 = b2.pjrt_params(&OpPoint::new(256, 6, 6, 8), &w, &x);
    let p4 = b4.pjrt_params(&OpPoint::new(512, 6, 6, 8), &w, &x);
    assert_eq!(p2[pvec::IDX_N_ACTIVE], p4[pvec::IDX_N_ACTIVE]);
    assert_ne!(p2[pvec::IDX_BANKS], p4[pvec::IDX_BANKS]);
}
