//! Adaptive-precision contract tests: cache-key separation from every
//! fixed-trials record, record-format compatibility, the stopping
//! rule's accuracy promise, and the CLI's mutual-exclusion guard.
//!
//! The load-bearing invariant: `--precision` is a *new* cache-key
//! dimension. Fixed-trials keys (and record bytes) are byte-identical
//! to what they were before adaptive runs existed, and an adaptive
//! record can never be served for a fixed-trials request or vice versa.

use imclim::arch::pvec;
use imclim::coordinator::SweepPoint;
use imclim::engine::{cache_key, ResultCache};
use imclim::mc::{self, ArchKind, InputDist, ADAPTIVE_MAX_TRIALS};

fn qs_params(n: usize) -> [f64; pvec::P] {
    let mut p = [0.0; pvec::P];
    p[pvec::IDX_N_ACTIVE] = n as f64;
    p[pvec::IDX_BX] = 6.0;
    p[pvec::IDX_BW] = 6.0;
    p[pvec::IDX_B_ADC] = 8.0;
    p[pvec::QS_IDX_SIGMA_D] = 0.107;
    p[pvec::QS_IDX_K_H] = 55.0;
    p[pvec::QS_IDX_V_C] = 55.0;
    p
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("imclim-adaptive-{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn adaptive_keys_are_disjoint_from_every_fixed_trials_key() {
    let p = qs_params(64);
    let fixed: Vec<String> = [1usize, 64, 256, 2048, 65536, ADAPTIVE_MAX_TRIALS]
        .iter()
        .map(|&t| {
            cache_key(
                &SweepPoint::new("f", ArchKind::Qs, p).with_trials(t).with_seed(7),
                "native@test",
            )
        })
        .collect();
    let adaptive: Vec<String> = [0.25f64, 0.5, 1.0]
        .iter()
        .map(|&pr| {
            cache_key(
                &SweepPoint::new("a", ArchKind::Qs, p)
                    .with_trials(ADAPTIVE_MAX_TRIALS)
                    .with_seed(7)
                    .with_precision(pr),
                "native@test",
            )
        })
        .collect();
    // every adaptive key differs from every fixed key — including the
    // fixed key at exactly the adaptive cap's trial count
    for (i, a) in adaptive.iter().enumerate() {
        for (j, f) in fixed.iter().enumerate() {
            assert_ne!(a, f, "adaptive[{i}] aliases fixed[{j}]");
        }
    }
    // the precision value itself participates in the key
    assert_ne!(adaptive[0], adaptive[1]);
    assert_ne!(adaptive[1], adaptive[2]);
    // and the key is a pure content address: same content, same key
    let again = cache_key(
        &SweepPoint::new("other-label", ArchKind::Qs, p)
            .with_trials(ADAPTIVE_MAX_TRIALS)
            .with_seed(7)
            .with_precision(0.25),
        "native@test",
    );
    assert_eq!(adaptive[0], again, "display id must not participate");
}

#[test]
fn fixed_records_carry_no_precision_field_and_adaptive_records_do() {
    let dir = tmp_dir("records");
    let cache = ResultCache::new(&dir, "native@test");
    let p = qs_params(32);

    let fixed = SweepPoint::new("fixed", ArchKind::Qs, p).with_trials(512).with_seed(3);
    let m_fixed = mc::measure(&mc::simulate(ArchKind::Qs, &p, 512, 3, InputDist::Uniform));
    cache.store(&fixed, &m_fixed).unwrap();
    let text = std::fs::read_to_string(dir.join(format!("{}.json", cache.key(&fixed)))).unwrap();
    assert!(
        !text.contains("precision_db"),
        "fixed-trials record bytes must stay exactly as before adaptive \
         runs existed: {text}"
    );

    let run = mc::simulate_adaptive(ArchKind::Qs, &p, 1.0, 3, InputDist::Uniform, 1 << 13);
    let adaptive = SweepPoint::new("adaptive", ArchKind::Qs, p)
        .with_trials(1 << 13)
        .with_seed(3)
        .with_precision(1.0);
    cache.store(&adaptive, &run.measured).unwrap();
    let text =
        std::fs::read_to_string(dir.join(format!("{}.json", cache.key(&adaptive)))).unwrap();
    assert!(text.contains("precision_db"), "{text}");

    // both round-trip bit-exactly, each from its own record
    let got_fixed = cache.load(&fixed).unwrap();
    assert_eq!(got_fixed.snr_t_db.to_bits(), m_fixed.snr_t_db.to_bits());
    assert_eq!(got_fixed.trials, 512);
    let got_adaptive = cache.load(&adaptive).unwrap();
    assert_eq!(
        got_adaptive.snr_t_db.to_bits(),
        run.measured.snr_t_db.to_bits()
    );
    assert_eq!(got_adaptive.trials, run.measured.trials);
    assert_ne!(
        got_adaptive.trials, 512,
        "adaptive record reports the stopping rule's actual trial count"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn adaptive_run_brackets_the_large_fixed_ensemble_within_its_half_width() {
    // Accuracy promise: the adaptive estimate agrees with a much larger
    // fixed ensemble to within the half-width it reports — while
    // spending fewer trials than the fixed default of 2048. The truth
    // run shares the seed, so the adaptive ensemble is a prefix of it
    // and the comparison is deterministic.
    let p = qs_params(512);
    let truth = mc::measure(&mc::simulate(
        ArchKind::Qs,
        &p,
        1 << 14,
        0xACC,
        InputDist::Uniform,
    ));
    let run = mc::simulate_adaptive(
        ArchKind::Qs,
        &p,
        1.0,
        0xACC,
        InputDist::Uniform,
        ADAPTIVE_MAX_TRIALS,
    );
    assert!(run.converged, "half_width={}", run.half_width_db);
    assert!(run.half_width_db <= 1.0);
    let trials = run.measured.trials as usize;
    assert_eq!(trials % mc::CHUNK_TRIALS, 0);
    assert!(
        trials < 2048,
        "adaptive spent {trials} trials, fixed default is 2048"
    );
    // 0.25 dB slack: the 16k-trial truth has residual MC error of its own
    for (a, t, name) in [
        (run.measured.snr_a_total_db, truth.snr_a_total_db, "snr_a"),
        (run.measured.snr_t_db, truth.snr_t_db, "snr_t"),
    ] {
        assert!(
            (a - t).abs() <= run.half_width_db + 0.25,
            "{name}: adaptive {a:.3} dB vs truth {t:.3} dB \
             (half-width {:.3})",
            run.half_width_db
        );
    }
}

#[test]
fn cli_rejects_precision_combined_with_trials() {
    let exe = env!("CARGO_BIN_EXE_imclim");
    let out = std::process::Command::new(exe)
        .args([
            "sweep", "--arch", "qs", "--n", "16", "--b-adc", "6", "--precision", "0.5",
            "--trials", "100",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "conflicting flags must fail");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("mutually exclusive"), "stderr: {stderr}");
}

#[test]
fn cli_rejects_nonpositive_or_garbage_precision() {
    let exe = env!("CARGO_BIN_EXE_imclim");
    for (bad, needle) in [("-1", "positive finite"), ("zero-ish", "dB half-width")] {
        let out = std::process::Command::new(exe)
            .args(["sweep", "--arch", "qs", "--n", "16", "--b-adc", "6", "--precision", bad])
            .output()
            .unwrap();
        assert!(!out.status.success(), "--precision {bad} must fail");
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(stderr.contains(needle), "--precision {bad}: {stderr}");
    }
}

#[test]
fn cli_adaptive_sweep_reruns_byte_identically_from_cache() {
    let exe = env!("CARGO_BIN_EXE_imclim");
    let dir = tmp_dir("cli-sweep");
    let args = [
        "sweep", "--arch", "qs", "--n", "16,24", "--b-adc", "5,6", "--precision", "2.0",
        "--workers", "2",
    ];
    let mut csvs = Vec::new();
    for pass in 0..2 {
        let out = std::process::Command::new(exe)
            .args(args)
            .arg("--out-dir")
            .arg(&dir)
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "pass {pass}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        csvs.push(std::fs::read(dir.join("sweep.csv")).unwrap());
    }
    // adaptive records landed in the cache and the warm rerun (which
    // served them) reproduced the cold CSV byte-for-byte
    assert!(!csvs[0].is_empty());
    assert_eq!(csvs[0], csvs[1], "warm adaptive rerun is byte-identical");
    let records = std::fs::read_dir(dir.join("cache"))
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| {
            let name = e.file_name();
            let name = name.to_string_lossy().into_owned();
            name.ends_with(".json") && name != "manifest.json"
        })
        .count();
    assert_eq!(records, 4, "one adaptive record per grid point");
    for entry in std::fs::read_dir(dir.join("cache")).unwrap() {
        let path = entry.unwrap().path();
        if path.file_name().unwrap() == "manifest.json" {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("precision_db"), "{}", path.display());
    }
    let _ = std::fs::remove_dir_all(&dir);
}
