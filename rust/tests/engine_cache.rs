//! Engine + result-cache integration: a warm re-run performs zero
//! Monte-Carlo recomputation and is bit-identical to the cold run; cache
//! keys react to every content field; corrupted records fall back to
//! recompute instead of erroring.

use std::path::{Path, PathBuf};

use imclim::arch::pvec;
use imclim::coordinator::{Backend, SweepOptions, SweepPoint};
use imclim::engine::{cache_key, Engine};
use imclim::mc::{ArchKind, InputDist};

fn qs_point(id: &str, n: usize, seed: u64, trials: usize) -> SweepPoint {
    let mut p = [0.0; pvec::P];
    p[pvec::IDX_N_ACTIVE] = n as f64;
    p[pvec::IDX_BX] = 6.0;
    p[pvec::IDX_BW] = 6.0;
    p[pvec::IDX_B_ADC] = 8.0;
    p[pvec::QS_IDX_SIGMA_D] = 0.1;
    p[pvec::QS_IDX_K_H] = 60.0;
    p[pvec::QS_IDX_V_C] = 60.0;
    SweepPoint::new(id, ArchKind::Qs, p)
        .with_trials(trials)
        .with_seed(seed)
}

/// Fresh (pre-cleaned) cache directory for one test.
fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("imclim-engine-test-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn engine(dir: &Path) -> Engine {
    Engine::new(
        Backend::Native,
        SweepOptions {
            workers: 4,
            verbose: false,
        },
    )
    .with_cache(dir.to_path_buf())
}

#[test]
fn warm_rerun_recomputes_nothing_and_is_bit_identical() {
    let dir = tmp_dir("warm");
    let mk = || -> Vec<SweepPoint> {
        (0..6)
            .map(|i| qs_point(&format!("p{i}"), 32 + 8 * i, i as u64, 200))
            .collect()
    };
    let e = engine(&dir);
    let (cold, s1) = e.run_with_stats(mk());
    assert_eq!(s1.hits, 0);
    assert_eq!(s1.misses, 6);
    assert_eq!(s1.errors, 0);

    let (warm, s2) = e.run_with_stats(mk());
    assert_eq!(s2.misses, 0, "warm run must not recompute anything");
    assert_eq!(s2.hits, 6);
    for (a, b) in cold.iter().zip(&warm) {
        assert_eq!(a.index, b.index);
        assert_eq!(a.id, b.id);
        assert!(b.cached, "warm results are flagged as cached");
        assert!(b.error.is_none());
        // every measured field is bit-identical to the cold run
        assert_eq!(a.measured.sigma_yo2.to_bits(), b.measured.sigma_yo2.to_bits());
        assert_eq!(a.measured.sigma_qiy2.to_bits(), b.measured.sigma_qiy2.to_bits());
        assert_eq!(
            a.measured.sigma_eta_a2.to_bits(),
            b.measured.sigma_eta_a2.to_bits()
        );
        assert_eq!(a.measured.sigma_qy2.to_bits(), b.measured.sigma_qy2.to_bits());
        assert_eq!(
            a.measured.sqnr_qiy_db.to_bits(),
            b.measured.sqnr_qiy_db.to_bits()
        );
        assert_eq!(a.measured.snr_a_db.to_bits(), b.measured.snr_a_db.to_bits());
        assert_eq!(
            a.measured.snr_a_total_db.to_bits(),
            b.measured.snr_a_total_db.to_bits()
        );
        assert_eq!(a.measured.snr_t_db.to_bits(), b.measured.snr_t_db.to_bits());
        assert_eq!(a.measured.trials, b.measured.trials);
    }
    // the manifest indexes every point
    let manifest = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    for r in &cold {
        assert!(manifest.contains(&r.id), "manifest lists {}", r.id);
    }
}

#[test]
fn partial_overlap_computes_only_the_new_points() {
    let dir = tmp_dir("partial");
    let e = engine(&dir);
    let (_, s1) = e.run_with_stats(vec![qs_point("a", 32, 1, 128), qs_point("b", 48, 2, 128)]);
    assert_eq!(s1.misses, 2);
    // one old point, one new point, interleaved
    let (res, s2) = e.run_with_stats(vec![
        qs_point("c", 64, 3, 128),
        qs_point("a", 32, 1, 128),
    ]);
    assert_eq!(s2.hits, 1);
    assert_eq!(s2.misses, 1);
    assert_eq!(res[0].id, "c");
    assert!(!res[0].cached);
    assert_eq!(res[1].id, "a");
    assert!(res[1].cached);
}

#[test]
fn key_reacts_to_every_content_field_but_not_the_label() {
    let base = qs_point("k", 64, 7, 256);
    let key = cache_key(&base, "native");
    assert_eq!(key.len(), 32);

    let mut trials = base.clone();
    trials.trials = 512;
    assert_ne!(cache_key(&trials, "native"), key, "trials");

    let mut seed = base.clone();
    seed.seed = 8;
    assert_ne!(cache_key(&seed, "native"), key, "seed");

    let mut dist = base.clone();
    dist.dist = InputDist::ClippedGaussian { sx: 0.3, sw: 0.3 };
    assert_ne!(cache_key(&dist, "native"), key, "dist");

    let mut params = base.clone();
    params.params[pvec::IDX_B_ADC] += 1.0;
    assert_ne!(cache_key(&params, "native"), key, "params");

    let mut kind = base.clone();
    kind.kind = ArchKind::Qr;
    assert_ne!(cache_key(&kind, "native"), key, "kind");

    assert_ne!(cache_key(&base, "pjrt"), key, "backend");

    // content-addressed: the display label does not matter
    let mut renamed = base.clone();
    renamed.id = "some/other/label".into();
    assert_eq!(cache_key(&renamed, "native"), key, "label must not matter");
}

#[test]
fn corrupted_record_falls_back_to_recompute() {
    let dir = tmp_dir("corrupt");
    let e = engine(&dir);
    let mk = || vec![qs_point("c0", 48, 3, 128)];
    let (cold, _) = e.run_with_stats(mk());

    let key = cache_key(&mk()[0], &Backend::Native.cache_id());
    let record = dir.join(format!("{key}.json"));
    assert!(record.exists(), "record written at {}", record.display());
    std::fs::write(&record, "{ definitely not json").unwrap();

    let (again, stats) = e.run_with_stats(mk());
    assert_eq!(stats.misses, 1, "corrupt record must be treated as a miss");
    assert_eq!(stats.hits, 0);
    assert!(again[0].error.is_none(), "recompute succeeds, no error");
    assert_eq!(
        cold[0].measured.snr_t_db.to_bits(),
        again[0].measured.snr_t_db.to_bits(),
        "recomputed value matches the original"
    );

    // and the repaired record serves the next run
    let (_, healed) = e.run_with_stats(mk());
    assert_eq!(healed.hits, 1);
}
