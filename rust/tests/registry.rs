//! Registry acceptance: a cache packed into a portable artifact,
//! pushed through a `file://` or `http://` registry and pulled on the
//! other side, is byte-identical to the source cache — so a warm sweep
//! against the pulled cache performs zero Monte-Carlo and emits a
//! byte-identical CSV. Tampered or truncated artifacts fail `verify`
//! (and never reach a cache directory), and pulling into a non-empty
//! cache follows exactly the `imclim merge` collision rules.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpListener;
use std::path::{Path, PathBuf};

use imclim::arch::pvec;
use imclim::coordinator::{Backend, SweepOptions, SweepPoint};
use imclim::engine::{Engine, MANIFEST_FILE};
use imclim::mc::ArchKind;
use imclim::registry::{
    open_store, pack, pull, push, verify, FileStore, ARTIFACT_FILE, PAYLOAD_FILE,
};

fn qs_point(id: &str, n: usize, seed: u64) -> SweepPoint {
    let mut p = [0.0; pvec::P];
    p[pvec::IDX_N_ACTIVE] = n as f64;
    p[pvec::IDX_BX] = 5.0;
    p[pvec::IDX_BW] = 5.0;
    p[pvec::IDX_B_ADC] = 7.0;
    p[pvec::QS_IDX_SIGMA_D] = 0.1;
    p[pvec::QS_IDX_K_H] = 50.0;
    p[pvec::QS_IDX_V_C] = 50.0;
    SweepPoint::new(id, ArchKind::Qs, p)
        .with_trials(96)
        .with_seed(seed)
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("imclim-registry-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn engine(dir: &Path) -> Engine {
    Engine::new(
        Backend::Native,
        SweepOptions {
            workers: 2,
            verbose: false,
        },
    )
    .with_cache(dir.to_path_buf())
}

/// Every file in a directory, name -> bytes (non-recursive).
fn dir_bytes(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    let mut out = BTreeMap::new();
    for entry in std::fs::read_dir(dir).unwrap().flatten() {
        if entry.path().is_file() {
            let name = entry.file_name().to_string_lossy().into_owned();
            out.insert(name, std::fs::read(entry.path()).unwrap());
        }
    }
    out
}

/// A populated cache of real engine results.
fn populated_cache(name: &str) -> (PathBuf, Vec<SweepPoint>) {
    let dir = tmp_dir(name);
    let points: Vec<SweepPoint> = (0..6)
        .map(|i| qs_point(&format!("reg/{i}"), 16 + 4 * i, i as u64))
        .collect();
    engine(&dir).run(points.clone());
    (dir, points)
}

#[test]
fn pack_push_pull_roundtrip_is_byte_identical_and_serves_warm() {
    let (cache, points) = populated_cache("roundtrip-src");
    let artifact = tmp_dir("roundtrip-artifact");
    let report = pack(&cache, &artifact, "test pack").unwrap();
    assert_eq!(report.records, 6);
    let v = verify(&artifact).unwrap();
    assert_eq!(v.id, report.id);
    assert_eq!(v.backend, Backend::Native.cache_id());

    let store = FileStore::new(tmp_dir("roundtrip-registry"));
    push(&artifact, &store).unwrap();

    // pull into a fresh cache dir: the full record set plus the label
    // manifest arrive byte-identical to the source cache
    let fresh = tmp_dir("roundtrip-fresh");
    let pulled = pull(&store, &fresh, None).unwrap();
    assert_eq!(pulled.copied, 6);
    assert!(pulled.collisions.is_empty());
    assert_eq!(pulled.backends, vec![Backend::Native.cache_id()]);
    let a = dir_bytes(&cache);
    let b = dir_bytes(&fresh);
    assert_eq!(
        a.keys().collect::<Vec<_>>(),
        b.keys().collect::<Vec<_>>(),
        "same file set (records + {MANIFEST_FILE})"
    );
    for (name, bytes) in &a {
        assert_eq!(bytes, &b[name], "byte-identical: {name}");
    }

    // ...so a re-run against the pulled cache does zero Monte-Carlo
    let (results, stats) = engine(&fresh).run_with_stats(points);
    assert_eq!(stats.misses, 0, "warm run performs zero Monte-Carlo");
    assert_eq!(stats.hits, 6);
    assert!(results.iter().all(|r| r.error.is_none()));
}

#[test]
fn single_byte_tamper_and_truncation_fail_verify() {
    let (cache, _) = populated_cache("tamper-src");
    let artifact = tmp_dir("tamper-artifact");
    pack(&cache, &artifact, "").unwrap();
    let payload = std::fs::read(artifact.join(PAYLOAD_FILE)).unwrap();

    for idx in [11, payload.len() / 3, payload.len() / 2, payload.len() - 1] {
        let mut bad = payload.clone();
        bad[idx] ^= 0x01;
        std::fs::write(artifact.join(PAYLOAD_FILE), &bad).unwrap();
        assert!(verify(&artifact).is_err(), "flip at byte {idx} must fail");
    }
    for keep in [0, 10, payload.len() / 2, payload.len() - 1] {
        std::fs::write(artifact.join(PAYLOAD_FILE), &payload[..keep]).unwrap();
        assert!(verify(&artifact).is_err(), "truncation to {keep} must fail");
    }
    std::fs::write(artifact.join(PAYLOAD_FILE), &payload).unwrap();
    verify(&artifact).unwrap();
}

#[test]
fn manifest_record_count_mismatch_fails_verify() {
    let (cache, _) = populated_cache("count-src");
    let artifact = tmp_dir("count-artifact");
    pack(&cache, &artifact, "").unwrap();
    let text = std::fs::read_to_string(artifact.join(ARTIFACT_FILE)).unwrap();
    let bad = text.replace("\"record_count\":6", "\"record_count\":7");
    assert_ne!(bad, text, "fixture should contain the count field");
    std::fs::write(artifact.join(ARTIFACT_FILE), &bad).unwrap();
    let err = verify(&artifact).unwrap_err().to_string();
    assert!(err.contains("record count mismatch"), "{err}");
}

#[test]
fn pull_into_nonempty_cache_follows_merge_collision_rules() {
    let (cache, points) = populated_cache("nonempty-src");
    let artifact = tmp_dir("nonempty-artifact");
    pack(&cache, &artifact, "").unwrap();
    let store = FileStore::new(tmp_dir("nonempty-registry"));
    push(&artifact, &store).unwrap();

    // destination computed a subset itself (identical payloads) and
    // additionally holds one record whose payload differs
    let dst = tmp_dir("nonempty-dst");
    engine(&dst).run(points[..2].to_vec());
    let colliding = dir_bytes(&dst)
        .keys()
        .find(|k| k.ends_with(".json") && *k != MANIFEST_FILE)
        .unwrap()
        .clone();
    std::fs::write(dst.join(&colliding), b"{\"v\": \"locally different\"}").unwrap();

    let report = pull(&store, &dst, None).unwrap();
    assert_eq!(report.copied, 4, "only the missing records are copied");
    assert_eq!(report.identical, 1, "one locally-computed twin");
    assert_eq!(report.collisions.len(), 1, "the doctored record collides");
    // destination copy wins, exactly like `imclim merge`
    assert_eq!(
        std::fs::read(dst.join(&colliding)).unwrap(),
        b"{\"v\": \"locally different\"}"
    );
}

/// A minimal single-threaded HTTP file server over a temp dir: GET
/// serves files (404 when absent), PUT stores them. Runs until the
/// listener is dropped; good enough to exercise the real TCP client.
fn spawn_http_registry(root: PathBuf) -> (u16, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let port = listener.local_addr().unwrap().port();
    let handle = std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut stream) = stream else { break };
            let mut raw = Vec::new();
            let mut buf = [0u8; 8192];
            let header_end = loop {
                match raw.windows(4).position(|w| w == b"\r\n\r\n") {
                    Some(i) => break i,
                    None => match stream.read(&mut buf) {
                        Ok(0) => break usize::MAX,
                        Ok(n) => raw.extend_from_slice(&buf[..n]),
                        Err(_) => break usize::MAX,
                    },
                }
            };
            if header_end == usize::MAX {
                continue;
            }
            let head = String::from_utf8_lossy(&raw[..header_end]).into_owned();
            let mut lines = head.split("\r\n");
            let request = lines.next().unwrap_or("").to_string();
            let mut parts = request.split_whitespace();
            let (method, path) = (
                parts.next().unwrap_or("").to_string(),
                parts.next().unwrap_or("/").trim_start_matches('/').to_string(),
            );
            let content_length: usize = lines
                .filter_map(|l| l.split_once(':'))
                .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
                .and_then(|(_, v)| v.trim().parse().ok())
                .unwrap_or(0);
            let mut body = raw[header_end + 4..].to_vec();
            while body.len() < content_length {
                match stream.read(&mut buf) {
                    Ok(0) | Err(_) => break,
                    Ok(n) => body.extend_from_slice(&buf[..n]),
                }
            }
            let reply = match method.as_str() {
                "GET" => match std::fs::read(root.join(&path)) {
                    Ok(data) => {
                        let mut r = format!(
                            "HTTP/1.1 200 OK\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
                            data.len()
                        )
                        .into_bytes();
                        r.extend_from_slice(&data);
                        r
                    }
                    Err(_) => b"HTTP/1.1 404 Not Found\r\nContent-Length: 0\r\nConnection: close\r\n\r\n".to_vec(),
                },
                "PUT" => {
                    let target = root.join(&path);
                    if let Some(parent) = target.parent() {
                        let _ = std::fs::create_dir_all(parent);
                    }
                    std::fs::write(&target, &body).unwrap();
                    b"HTTP/1.1 201 Created\r\nContent-Length: 0\r\nConnection: close\r\n\r\n".to_vec()
                }
                _ => b"HTTP/1.1 405 Method Not Allowed\r\nContent-Length: 0\r\nConnection: close\r\n\r\n".to_vec(),
            };
            let _ = stream.write_all(&reply);
            let _ = stream.flush();
        }
    });
    (port, handle)
}

#[test]
fn http_registry_push_pull_roundtrip() {
    let (cache, _) = populated_cache("http-src");
    let artifact = tmp_dir("http-artifact");
    pack(&cache, &artifact, "").unwrap();

    let (port, _server) = spawn_http_registry(tmp_dir("http-registry-root"));
    let store = open_store(&format!("http://127.0.0.1:{port}/")).unwrap();
    let pushed = push(&artifact, store.as_ref()).unwrap();
    assert!(!pushed.already_present);
    // idempotent re-push over HTTP
    assert!(push(&artifact, store.as_ref()).unwrap().already_present);

    let fresh = tmp_dir("http-fresh");
    let report = pull(store.as_ref(), &fresh, None).unwrap();
    assert_eq!(report.copied, 6);
    assert!(report.collisions.is_empty());
    let a = dir_bytes(&cache);
    let b = dir_bytes(&fresh);
    assert_eq!(a.keys().collect::<Vec<_>>(), b.keys().collect::<Vec<_>>());
    for (name, bytes) in &a {
        assert_eq!(bytes, &b[name], "byte-identical over HTTP: {name}");
    }
}

/// Regression: a chunked response from a keep-alive server must
/// resolve as soon as the terminating `0\r\n\r\n` arrives. The old
/// decoder buffered to EOF, so a server that (correctly) held the
/// connection open stalled every GET until the 30s read timeout.
#[test]
fn chunked_response_from_keep_alive_server_resolves_without_waiting_for_eof() {
    use imclim::registry::http::HttpEndpoint;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let port = listener.local_addr().unwrap().port();
    // the server never closes its side: it answers chunked, then holds
    // the socket open (keep-alive) far longer than the test tolerates
    std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        let mut raw = Vec::new();
        let mut buf = [0u8; 1024];
        while !raw.windows(4).any(|w| w == b"\r\n\r\n") {
            match stream.read(&mut buf) {
                Ok(0) | Err(_) => return,
                Ok(n) => raw.extend_from_slice(&buf[..n]),
            }
        }
        stream
            .write_all(
                b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\n\
                  4\r\nWiki\r\n5\r\npedia\r\n0\r\n\r\n",
            )
            .unwrap();
        stream.flush().unwrap();
        std::thread::sleep(std::time::Duration::from_secs(60));
    });

    let ep = HttpEndpoint::parse(&format!("http://127.0.0.1:{port}/")).unwrap();
    let started = std::time::Instant::now();
    let body = ep.get("chunked").unwrap().expect("200 response");
    assert_eq!(body, b"Wikipedia");
    assert!(
        started.elapsed() < std::time::Duration::from_secs(3),
        "decoder must resolve on the chunk terminator, not wait for \
         EOF/timeout (took {:?})",
        started.elapsed()
    );
}

// ---------------------------------------------------------------------
// End-to-end through the CLI binary.
// ---------------------------------------------------------------------

fn run_cli(args: &[&str]) -> std::process::Output {
    std::process::Command::new(env!("CARGO_BIN_EXE_imclim"))
        .args(args)
        .output()
        .unwrap()
}

fn ok_stdout(args: &[&str]) -> String {
    let out = run_cli(args);
    assert!(
        out.status.success(),
        "imclim {args:?} failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn cli_pack_push_pull_rerun_is_byte_identical_with_zero_monte_carlo() {
    let src = tmp_dir("cli-src");
    let src_s = src.to_str().unwrap();
    let sweep = [
        "sweep", "--arch", "qs", "--n", "8,12,16", "--b-adc", "4,5", "--trials", "48",
        "--workers", "2",
    ];
    let mut cold = sweep.to_vec();
    cold.extend(["--out-dir", src_s]);
    ok_stdout(&cold);

    ok_stdout(&["cache", "pack", "--out-dir", src_s]);
    let verified = ok_stdout(&["cache", "verify", "--out-dir", src_s]);
    assert!(verified.contains("OK"), "{verified}");

    // stats reports the backend cache id and the artifact provenance
    let stats = ok_stdout(&["cache", "stats", "--out-dir", src_s]);
    assert!(stats.contains("backend: native@"), "{stats}");
    assert!(stats.contains("artifact: schema 1"), "{stats}");
    assert!(stats.contains("packed by imclim"), "{stats}");

    let registry = tmp_dir("cli-registry");
    let url = format!("file://{}", registry.display());
    ok_stdout(&["cache", "push", &url, "--out-dir", src_s]);

    // a different machine: pull, then re-run the same sweep warm
    let dst = tmp_dir("cli-dst");
    let dst_s = dst.to_str().unwrap();
    let pulled = ok_stdout(&["cache", "pull", &url, "--out-dir", dst_s]);
    assert!(pulled.contains("6 new records"), "{pulled}");
    let mut warm = sweep.to_vec();
    warm.extend(["--out-dir", dst_s]);
    let warm_out = ok_stdout(&warm);
    assert!(
        warm_out.contains("(6 cache hits, 0 computed)"),
        "pulled cache must serve the whole sweep: {warm_out}"
    );
    assert_eq!(
        std::fs::read(src.join("sweep.csv")).unwrap(),
        std::fs::read(dst.join("sweep.csv")).unwrap(),
        "sweep.csv byte-identical across the registry round-trip"
    );
}

#[test]
fn cli_verify_exits_nonzero_on_tampered_payload() {
    let (cache, _) = populated_cache("cli-tamper-src");
    let artifact = tmp_dir("cli-tamper-artifact");
    pack(&cache, &artifact, "").unwrap();
    let payload_path = artifact.join(PAYLOAD_FILE);
    let mut payload = std::fs::read(&payload_path).unwrap();
    let mid = payload.len() / 2;
    payload[mid] ^= 0xff;
    std::fs::write(&payload_path, &payload).unwrap();
    let out = run_cli(&["cache", "verify", "--artifact-dir", artifact.to_str().unwrap()]);
    assert!(!out.status.success(), "tampered artifact must exit nonzero");
}

#[test]
fn cli_merge_strict_exits_nonzero_and_lists_colliding_keys() {
    let dst = tmp_dir("cli-strict-out");
    let pre = dst.join("cache");
    let src = tmp_dir("cli-strict-src");
    std::fs::create_dir_all(&pre).unwrap();
    std::fs::create_dir_all(&src).unwrap();
    std::fs::write(pre.join("kboth.json"), b"{\"v\": 1}").unwrap();
    std::fs::write(src.join("kboth.json"), b"{\"v\": 2}").unwrap();
    std::fs::write(src.join("konly.json"), b"{\"v\": 3}").unwrap();

    // without --strict: a warning, exit 0
    let out = run_cli(&[
        "merge",
        src.to_str().unwrap(),
        "--out-dir",
        dst.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "plain merge stays a warning");

    // put the collision back and re-merge strictly
    std::fs::write(pre.join("kboth.json"), b"{\"v\": 1}").unwrap();
    let out = run_cli(&[
        "merge",
        src.to_str().unwrap(),
        "--strict",
        "--out-dir",
        dst.to_str().unwrap(),
    ]);
    assert!(!out.status.success(), "--strict must exit nonzero");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("kboth"), "colliding key is listed: {err}");
    assert!(err.contains("1 key(s) collided"), "{err}");
}
