//! PJRT runtime integration: load the AOT artifacts and execute them.
//! Requires `make artifacts` to have run (skips with a message if not).

use std::path::PathBuf;

use imclim::arch::pvec;
use imclim::coordinator::{ArchRequest, MlpRequest, MlpWeights, PjrtService};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn smoke_round_trip() {
    let dir = require_artifacts!();
    let service = PjrtService::spawn(dir, 2);
    let out = service.handle().smoke().unwrap();
    assert_eq!(out, vec![5.0, 5.0, 9.0, 9.0]);
}

fn qs_params(n: f64) -> [f64; pvec::P] {
    let mut p = [0.0; pvec::P];
    p[pvec::IDX_N_ACTIVE] = n;
    p[pvec::IDX_BX] = 6.0;
    p[pvec::IDX_BW] = 6.0;
    p[pvec::IDX_B_ADC] = 8.0;
    p[pvec::QS_IDX_SIGMA_D] = 0.107;
    p[pvec::QS_IDX_K_H] = 48.0;
    p[pvec::QS_IDX_V_C] = 48.0;
    p
}

#[test]
fn qs_small_artifact_runs_and_is_seed_deterministic() {
    let dir = require_artifacts!();
    let service = PjrtService::spawn(dir, 2);
    let handle = service.handle();
    let (m, n_max) = handle.arch_shape("qs_arch_small").unwrap();
    assert_eq!((m, n_max), (16, 64));

    let x: Vec<f32> = (0..m * n_max).map(|i| (i % 97) as f32 / 97.0).collect();
    let w: Vec<f32> = (0..m * n_max)
        .map(|i| ((i % 53) as f32 / 26.5) - 1.0)
        .collect();
    let req = |seed: [f32; 2]| ArchRequest {
        artifact: "qs_arch_small".into(),
        x: x.clone(),
        w: w.clone(),
        seed,
        params: qs_params(48.0),
    };
    let a = handle.run_arch(req([1.0, 2.0])).unwrap();
    let b = handle.run_arch(req([1.0, 2.0])).unwrap();
    let c = handle.run_arch(req([3.0, 2.0])).unwrap();
    assert_eq!(a.len(), m);
    assert_eq!(a.y_hat, b.y_hat, "same seed, same outputs");
    assert_ne!(a.y_hat, c.y_hat, "different seed, different noise");
    // deterministic parts are seed-independent
    assert_eq!(a.y_ideal, c.y_ideal);
    assert_eq!(a.y_fx, c.y_fx);
    // and finite
    assert!(a.y_hat.iter().all(|v| v.is_finite()));
}

#[test]
fn all_arch_small_artifacts_noiseless_identity() {
    // With zero noise params and a wide ADC, y_a == y_fx on all three.
    let dir = require_artifacts!();
    let service = PjrtService::spawn(dir, 2);
    let handle = service.handle();
    for (artifact, vc_idx, vc) in [
        ("qs_arch_small", pvec::QS_IDX_V_C, 80.0),
        ("qr_arch_small", pvec::QR_IDX_V_C, 1.0),
        ("cm_arch_small", pvec::CM_IDX_V_C, 1.0),
    ] {
        let (m, n_max) = handle.arch_shape(artifact).unwrap();
        let mut p = [0.0; pvec::P];
        p[pvec::IDX_N_ACTIVE] = 32.0;
        p[pvec::IDX_BX] = 6.0;
        p[pvec::IDX_BW] = 6.0;
        p[pvec::IDX_B_ADC] = 14.0;
        p[vc_idx] = vc;
        if artifact == "qs_arch_small" {
            p[pvec::QS_IDX_K_H] = 1e9;
        }
        if artifact == "cm_arch_small" {
            p[pvec::CM_IDX_W_H] = 1e9;
        }
        let x: Vec<f32> = (0..m * n_max).map(|i| (i % 89) as f32 / 89.0).collect();
        let w: Vec<f32> = (0..m * n_max)
            .map(|i| ((i % 41) as f32 / 20.5) - 1.0)
            .collect();
        let out = handle
            .run_arch(ArchRequest {
                artifact: artifact.into(),
                x,
                w,
                seed: [5.0, 6.0],
                params: p,
            })
            .unwrap();
        for i in 0..out.len() {
            assert!(
                (out.y_a[i] - out.y_fx[i]).abs() < 1e-3,
                "{artifact}[{i}]: y_a {} != y_fx {}",
                out.y_a[i],
                out.y_fx[i]
            );
        }
    }
}

#[test]
fn mlp_artifact_matches_native_forward() {
    let dir = require_artifacts!();
    let service = PjrtService::spawn(dir, 2);
    let handle = service.handle();

    // a tiny deterministic network
    let mlp = imclim::dnn::Mlp::new(&[64, 128, 64, 10], 3);
    let weights = MlpWeights {
        w1: mlp.w[0].clone(),
        b1: mlp.b[0].clone(),
        w2: mlp.w[1].clone(),
        b2: mlp.b[1].clone(),
        w3: mlp.w[2].clone(),
        b3: mlp.b[2].clone(),
    };
    let batch = 256;
    let x: Vec<f32> = (0..batch * 64).map(|i| (i % 101) as f32 / 101.0).collect();
    let logits = handle
        .run_mlp(MlpRequest {
            x: x.clone(),
            weights,
            seed: [0.0, 0.0],
            sigmas: [0.0, 0.0, 0.0],
        })
        .unwrap();
    assert_eq!(logits.len(), batch * 10);
    // compare a few rows against the native forward
    for row in [0usize, 17, 255] {
        let native = mlp.forward(&x[row * 64..(row + 1) * 64]);
        for c in 0..10 {
            let diff = (logits[row * 10 + c] - native[c]).abs();
            assert!(diff < 1e-3, "row {row} class {c}: {diff}");
        }
    }
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let dir = require_artifacts!();
    let service = PjrtService::spawn(dir, 2);
    let err = service
        .handle()
        .arch_shape("definitely_not_an_artifact")
        .unwrap_err();
    assert!(err.to_string().contains("not in manifest"), "{err}");
}
