//! Remote worker fabric acceptance: `imclim worker` subprocesses
//! attach to an in-process `imclim serve` daemon, lease deterministic
//! shard slices of a submitted sweep, and publish results back as
//! verified cache artifacts. The merged run must be byte-identical to
//! the single-process CLI run — and stay that way when a worker is
//! SIGKILLed mid-shard (its lease times out, the shard re-queues) or
//! when the whole fleet dies (the coordinator falls back to local
//! execution).
//!
//! Jobs sample process-global metrics, so the in-process daemon tests
//! serialize on one mutex, same as `tests/serve.rs`.

use std::path::{Path, PathBuf};
use std::process::{Child, Stdio};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use imclim::cli::serve::{start_with, ServeHandle};
use imclim::registry::http::HttpEndpoint;
use imclim::util::json::Json;

static TEST_LOCK: Mutex<()> = Mutex::new(());

const GRID_POINTS: usize = 6; // arch qs × n {8,12,16} × b-adc {4,5}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("imclim-remote-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn sweep_body() -> &'static str {
    r#"{"cmd":"sweep","options":{"arch":"qs","n":"8,12,16","b-adc":"4,5",
        "trials":"48","workers":"2"}}"#
}

/// The same grid through the CLI binary; returns sweep.csv bytes.
fn cli_reference_csv(dir: &Path) -> Vec<u8> {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_imclim"))
        .args([
            "sweep", "--arch", "qs", "--n", "8,12,16", "--b-adc", "4,5", "--trials", "48",
            "--workers", "2", "--out-dir",
        ])
        .arg(dir)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "reference sweep failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    std::fs::read(dir.join("sweep.csv")).unwrap()
}

fn daemon(name: &str, lease_timeout: Duration) -> (ServeHandle, HttpEndpoint, PathBuf) {
    let out_dir = tmp_dir(name);
    let handle = start_with("127.0.0.1:0", out_dir.clone(), 64, lease_timeout).unwrap();
    let ep = HttpEndpoint::parse(&handle.base_url()).unwrap();
    (handle, ep, out_dir)
}

/// Spawn an `imclim worker` subprocess. `hold_ms` is the chaos dwell
/// between taking a lease and executing it — it makes "mid-shard"
/// deterministic: a worker holding a lease with a long dwell provably
/// has not finished it yet.
fn spawn_worker(test: &str, url: &str, name: &str, hold_ms: u64) -> Child {
    let scratch = tmp_dir(&format!("{test}-scratch-{name}"));
    std::process::Command::new(env!("CARGO_BIN_EXE_imclim"))
        .args([
            "worker",
            "--connect",
            url,
            "--name",
            name,
            "--poll-ms",
            "50",
            "--heartbeat-ms",
            "200",
            "--hold-ms",
        ])
        .arg(hold_ms.to_string())
        .arg("--scratch")
        .arg(&scratch)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .unwrap()
}

fn get_json(ep: &HttpEndpoint, rel: &str) -> Json {
    let (st, bytes) = ep.get_raw(rel).unwrap();
    assert_eq!(st, 200, "GET /{rel}");
    Json::parse(&String::from_utf8_lossy(&bytes)).unwrap()
}

/// `(name, leased)` per registered worker.
fn worker_rows(ep: &HttpEndpoint) -> Vec<(String, usize)> {
    get_json(ep, "workers")
        .get("workers")
        .and_then(Json::as_arr)
        .expect("workers array")
        .iter()
        .map(|w| {
            (
                w.get("name").and_then(|v| v.as_str()).unwrap().to_string(),
                w.get("leased").and_then(Json::as_usize).unwrap(),
            )
        })
        .collect()
}

fn wait_until<F: FnMut() -> bool>(what: &str, timeout: Duration, mut cond: F) {
    let deadline = Instant::now() + timeout;
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn submit(ep: &HttpEndpoint, body: &str) -> u64 {
    let (status, bytes) = ep.post("jobs", body.as_bytes(), "application/json").unwrap();
    let json = Json::parse(&String::from_utf8_lossy(&bytes)).unwrap_or(Json::Null);
    assert_eq!(status, 202, "submission accepted: {json:?}");
    json.get("id").and_then(Json::as_usize).expect("job id") as u64
}

fn wait_job(ep: &HttpEndpoint, id: u64) -> Json {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let json = get_json(ep, &format!("jobs/{id}"));
        let state = json.get("state").and_then(|v| v.as_str()).unwrap().to_string();
        if matches!(state.as_str(), "done" | "failed" | "canceled") {
            return json;
        }
        assert!(Instant::now() < deadline, "job {id} stuck in '{state}'");
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn metric(json: &Json, name: &str) -> usize {
    json.get(name)
        .and_then(Json::as_usize)
        .unwrap_or_else(|| panic!("status JSON lacks '{name}': {json:?}"))
}

fn job_events(ep: &HttpEndpoint, id: u64) -> String {
    let (st, bytes) = ep.get_raw(&format!("jobs/{id}/events")).unwrap();
    assert_eq!(st, 200, "events for job {id}");
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(unix)]
fn sigkill(child: &Child) {
    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    const SIGKILL: i32 = 9;
    assert_eq!(unsafe { kill(child.id() as i32, SIGKILL) }, 0);
}

#[test]
fn two_workers_compute_the_sweep_and_the_csv_is_cli_identical() {
    let _guard = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let reference = cli_reference_csv(&tmp_dir("two-cli-ref"));
    let (handle, ep, out_dir) = daemon("two", Duration::from_secs(10));

    let mut w1 = spawn_worker("two", &handle.base_url(), "alpha", 0);
    let mut w2 = spawn_worker("two", &handle.base_url(), "beta", 0);
    wait_until("both workers to register", Duration::from_secs(30), || {
        worker_rows(&ep).len() == 2
    });

    let id = submit(&ep, sweep_body());
    let status = wait_job(&ep, id);
    assert_eq!(status.get("state").and_then(|v| v.as_str()), Some("done"));
    // every Monte-Carlo trial ran in a worker process: the daemon's
    // own pass over the merged cache is purely warm
    assert_eq!(
        metric(&status, "points_computed"),
        0,
        "coordinator computed nothing: {status:?}"
    );
    assert_eq!(metric(&status, "cache_hits"), GRID_POINTS, "{status:?}");

    let (st, csv) = ep.get_raw(&format!("jobs/{id}/result")).unwrap();
    assert_eq!(st, 200);
    assert_eq!(csv, reference, "distributed CSV must match the CLI run byte-for-byte");

    // the per-shard lifecycle is visible in the job's event stream
    let events = job_events(&ep, id);
    assert!(events.contains("\"shard_leased\""), "{events}");
    assert!(events.contains("\"shard_completed\""), "{events}");

    // worker gauge answers at scrape time
    let (st, metrics) = ep.get_raw("metrics").unwrap();
    assert_eq!(st, 200);
    let metrics = String::from_utf8_lossy(&metrics).into_owned();
    assert!(metrics.contains("imclim_workers_registered 2"), "{metrics}");

    // cache records round-tripped through pack/push/pull verification:
    // a CLI run over the daemon's cache is fully warm and identical
    let warm_dir = tmp_dir("two-warm");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_imclim"))
        .args([
            "sweep", "--arch", "qs", "--n", "8,12,16", "--b-adc", "4,5", "--trials", "48",
            "--workers", "2", "--cache-dir",
        ])
        .arg(out_dir.join("cache"))
        .arg("--out-dir")
        .arg(&warm_dir)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("(6 cache hits, 0 computed)"),
        "worker records serve the whole grid: {stdout}"
    );
    assert_eq!(std::fs::read(warm_dir.join("sweep.csv")).unwrap(), reference);

    // draining the daemon sends the workers home with exit code 0
    handle.shutdown();
    assert!(w1.wait().unwrap().success(), "worker alpha exits 0");
    assert!(w2.wait().unwrap().success(), "worker beta exits 0");
}

#[cfg(unix)]
#[test]
fn killing_a_worker_mid_shard_requeues_it_and_the_job_still_completes() {
    let _guard = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let reference = cli_reference_csv(&tmp_dir("kill-cli-ref"));
    let (handle, ep, _out) = daemon("kill", Duration::from_secs(2));

    // the victim dwells 60s on any lease it takes — far past the 2s
    // lease timeout once its heartbeats stop; the survivor dwells 1.5s
    // so the victim provably gets one of the two shards
    let mut victim = spawn_worker("kill", &handle.base_url(), "victim", 60_000);
    let mut survivor = spawn_worker("kill", &handle.base_url(), "survivor", 1_500);
    wait_until("both workers to register", Duration::from_secs(30), || {
        worker_rows(&ep).len() == 2
    });

    let id = submit(&ep, sweep_body());
    wait_until("the victim to hold a lease", Duration::from_secs(30), || {
        worker_rows(&ep)
            .iter()
            .any(|(name, leased)| name == "victim" && *leased >= 1)
    });
    sigkill(&victim);
    let _ = victim.wait(); // reap the zombie; heartbeats are now gone

    // the lease times out, the shard re-queues to the survivor, and the
    // job completes with the exact single-process bytes
    let status = wait_job(&ep, id);
    assert_eq!(
        status.get("state").and_then(|v| v.as_str()),
        Some("done"),
        "{status:?}"
    );
    let (st, csv) = ep.get_raw(&format!("jobs/{id}/result")).unwrap();
    assert_eq!(st, 200);
    assert_eq!(csv, reference, "worker loss must not change a single byte");

    let events = job_events(&ep, id);
    assert!(
        events.contains("\"shard_requeued\""),
        "the re-queue is visible in the job's event stream: {events}"
    );
    assert!(events.contains("victim"), "{events}");

    let (st, metrics) = ep.get_raw("metrics").unwrap();
    assert_eq!(st, 200);
    let metrics = String::from_utf8_lossy(&metrics).into_owned();
    let requeues: f64 = metrics
        .lines()
        .find_map(|l| l.strip_prefix("imclim_shard_requeues_total "))
        .expect("requeue counter exported")
        .trim()
        .parse()
        .unwrap();
    assert!(requeues >= 1.0, "{metrics}");

    handle.shutdown();
    assert!(survivor.wait().unwrap().success(), "survivor exits 0");
}

#[cfg(unix)]
#[test]
fn losing_the_whole_fleet_falls_back_to_local_execution() {
    let _guard = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let reference = cli_reference_csv(&tmp_dir("fleet-cli-ref"));
    let (handle, ep, _out) = daemon("fleet", Duration::from_secs(1));

    // one worker -> the job becomes one shard (the whole grid)
    let mut only = spawn_worker("fleet", &handle.base_url(), "only", 60_000);
    wait_until("the worker to register", Duration::from_secs(30), || {
        worker_rows(&ep).len() == 1
    });
    let id = submit(&ep, sweep_body());
    wait_until("the worker to hold the lease", Duration::from_secs(30), || {
        worker_rows(&ep)
            .iter()
            .any(|(name, leased)| name == "only" && *leased >= 1)
    });
    sigkill(&only);
    let _ = only.wait();

    // nobody is left: the coordinator reaps the worker, re-queues the
    // shard, and runs it itself
    let status = wait_job(&ep, id);
    assert_eq!(
        status.get("state").and_then(|v| v.as_str()),
        Some("done"),
        "{status:?}"
    );
    assert_eq!(
        metric(&status, "points_computed"),
        GRID_POINTS,
        "the whole grid was computed locally: {status:?}"
    );
    let (st, csv) = ep.get_raw(&format!("jobs/{id}/result")).unwrap();
    assert_eq!(st, 200);
    assert_eq!(csv, reference);
    let events = job_events(&ep, id);
    assert!(events.contains("\"shard_requeued\""), "{events}");

    handle.shutdown();
}
