//! Design-space optimizer regression suite: golden frontier pins at the
//! 512-row reference configuration, optimizer/frontier consistency
//! properties (no dominated rows, axis-permutation and shard-count
//! invariance, every constrained answer on its domain frontier, MPC
//! agreement), the QS-vs-QR crossover of conclusion 3, and CLI-level
//! warm-vs-cold / multi-thread byte determinism of `imclim pareto`.

use imclim::engine::{parse_grid_f64, parse_grid_u32, parse_grid_usize};
use imclim::figures::uniform_stats;
use imclim::opt::{
    crossover, frontier, optimize, ArchChoice, Constraints, DesignPoint, Domain, Objective,
};
use imclim::tech::TechNode;

/// Relative-tolerance pin (same contract as golden_snr.rs).
fn pin(label: &str, actual: f64, golden: f64, rel: f64) {
    let err = ((actual - golden) / golden.abs().max(1e-300)).abs();
    assert!(
        err < rel,
        "{label}: actual {actual:.15e} vs golden {golden:.15e} (rel err {err:.2e})"
    );
}

/// The CLI's default search domain (the acceptance configuration):
/// `--arch qs,qr --n 64:512:64 --b-adc 4:10 --vwl 0.6:0.9:0.1 --co 3`.
fn acceptance_domain() -> Domain {
    Domain {
        archs: vec![ArchChoice::Qs, ArchChoice::Qr],
        nodes: vec![TechNode::n65()],
        vwls: parse_grid_f64("0.6:0.9:0.1").unwrap(),
        cos: parse_grid_f64("3").unwrap(),
        ns: parse_grid_usize("64:512:64").unwrap(),
        bxs: vec![6],
        bws: vec![6],
        b_adcs: parse_grid_u32("4:10").unwrap(),
    }
    .normalized()
    .unwrap()
}

/// Brute-force dominance filter over a full enumeration.
fn reference_frontier(points: &[DesignPoint]) -> Vec<&DesignPoint> {
    points
        .iter()
        .filter(|p| !points.iter().any(|q| q.dominates(p)))
        .collect()
}

#[test]
fn golden_frontier_at_512_row_reference() {
    // n = 512 restriction of the acceptance domain: the 512-row
    // reference configuration of golden_snr.rs. Hand-derived outcome:
    // every QS family collapses (headroom clipping at V_WL >= 0.7,
    // mismatch at 0.6 capping SNR_A at ~13.3 dB) at higher energy than
    // QR, so the frontier is exactly the QR C_o = 3 fF column, one
    // point per B_ADC (energy and SNR_T both strictly grow with bits).
    let (w, x) = uniform_stats();
    let d = Domain {
        ns: vec![512],
        ..acceptance_domain()
    }
    .normalized()
    .unwrap();
    let fr = frontier(&d, 1, &w, &x);
    assert_eq!(fr.points.len(), 7, "one frontier point per B_ADC in 4..=10");
    for (i, p) in fr.points.iter().enumerate() {
        assert_eq!(p.family.arch, ArchChoice::Qr);
        assert_eq!(p.family.n, 512);
        assert_eq!(p.family.c_ff, Some(3.0));
        assert_eq!(p.b_adc, 4 + i as u32, "sorted by energy == by B_ADC");
        assert_eq!(p.b_adc_mpc, 7, "eq. (15) assignment at SNR_A ~22 dB");
        pin("qr512_snr_a", p.snr_a_total_db, 21.990_261_132_279_12, 1e-9);
    }
    // exact closed-form pins (hand-derived from Table III + eqs. 11/14/25/26)
    pin("b4_snr_t", fr.points[0].snr_t_db, 15.657_330_402_719_50, 1e-9);
    pin("b4_energy", fr.points[0].energy_j, 1.364_407_512_175_014e-11, 1e-9);
    pin("b4_delay_ns", fr.points[0].delay_ns(), 0.9, 1e-9);
    pin("b7_snr_t", fr.points[3].snr_t_db, 21.767_634_095_714_89, 1e-9);
    pin("b7_energy", fr.points[3].energy_j, 2.287_585_752_175_014e-11, 1e-9);
    pin("b10_snr_t", fr.points[6].snr_t_db, 21.982_172_187_853_56, 1e-9);
    pin("b10_energy", fr.points[6].energy_j, 5.003_099_311_217_504e-10, 1e-9);
    pin("b10_delay_ns", fr.points[6].delay_ns(), 1.5, 1e-9);
}

#[test]
fn acceptance_frontier_matches_brute_force_with_no_dominated_row() {
    let (w, x) = uniform_stats();
    let d = acceptance_domain();
    let fr = frontier(&d, 1, &w, &x);
    // no reported point is dominated by any candidate in the domain
    let all = d.all_points(&w, &x);
    assert_eq!(all.len(), 280, "40 families x 7 B_ADC values");
    for p in &fr.points {
        assert!(
            !all.iter().any(|q| q.dominates(p)),
            "{} is dominated",
            p.label()
        );
    }
    // and the frontier is exactly the brute-force reference set
    let mut want = reference_frontier(&all);
    want.sort_by_key(|p| p.key());
    let mut got: Vec<&DesignPoint> = fr.points.iter().collect();
    got.sort_by_key(|p| p.key());
    assert_eq!(got.len(), want.len());
    for (g, r) in got.iter().zip(&want) {
        assert_eq!(g.key(), r.key());
        assert_eq!(g.energy_j.to_bits(), r.energy_j.to_bits());
        assert_eq!(g.snr_t_db.to_bits(), r.snr_t_db.to_bits());
        assert_eq!(g.delay_s.to_bits(), r.delay_s.to_bits());
    }
    // the cheapest frontier design: QR at the smallest array and B_ADC
    let first = &fr.points[0];
    assert_eq!(first.family.arch, ArchChoice::Qr);
    assert_eq!(first.family.n, 64);
    assert_eq!(first.b_adc, 4);
    pin("acc_min_energy", first.energy_j, 4.576_855_921_750_138e-12, 1e-9);
}

#[test]
fn frontier_invariant_under_axis_permutation_and_shards() {
    let (w, x) = uniform_stats();
    let canonical = Domain {
        archs: vec![ArchChoice::Qs, ArchChoice::Qr, ArchChoice::Cm],
        nodes: vec![TechNode::n65(), TechNode::n22()],
        vwls: vec![0.6, 0.7, 0.8],
        cos: vec![1.0, 3.0],
        ns: vec![64, 128],
        bxs: vec![4, 6],
        bws: vec![6],
        b_adcs: vec![4, 6, 8],
    };
    let permuted = Domain {
        archs: vec![ArchChoice::Cm, ArchChoice::Qr, ArchChoice::Qs],
        nodes: vec![TechNode::n22(), TechNode::n65()],
        vwls: vec![0.8, 0.6, 0.7],
        cos: vec![3.0, 1.0],
        ns: vec![128, 64],
        bxs: vec![6, 4],
        bws: vec![6],
        b_adcs: vec![8, 4, 6],
    };
    let base = frontier(&canonical.clone().normalized().unwrap(), 1, &w, &x);
    assert!(!base.points.is_empty());
    let perm = frontier(&permuted.normalized().unwrap(), 1, &w, &x);
    let same = |a: &DesignPoint, b: &DesignPoint| {
        a.key() == b.key()
            && a.energy_j.to_bits() == b.energy_j.to_bits()
            && a.snr_t_db.to_bits() == b.snr_t_db.to_bits()
            && a.delay_s.to_bits() == b.delay_s.to_bits()
    };
    assert_eq!(base.points.len(), perm.points.len(), "axis permutation");
    for (a, b) in base.points.iter().zip(&perm.points) {
        assert!(same(a, b), "{} vs {}", a.label(), b.label());
    }
    for shards in [2, 4, 9] {
        let sharded = frontier(&canonical.clone().normalized().unwrap(), shards, &w, &x);
        assert_eq!(base.points.len(), sharded.points.len(), "{shards} shards");
        for (a, b) in base.points.iter().zip(&sharded.points) {
            assert!(same(a, b), "{shards} shards: {} vs {}", a.label(), b.label());
        }
    }
}

#[test]
fn optimize_min_energy_sits_on_frontier_and_matches_mpc() {
    // Acceptance query: min energy subject to SNR_T >= 21.5 dB — the
    // 512-row reference's "SNR_A within 0.5 dB" operating point. The
    // smallest feasible B_ADC is then exactly the eq. (15) MPC
    // assignment, so the optimizer's bit choice must agree with MPC.
    let (w, x) = uniform_stats();
    let d = acceptance_domain();
    let report = optimize(
        &d,
        Objective::MinEnergy,
        &Constraints {
            snr_t_min_db: Some(21.5),
            ..Constraints::default()
        },
        &w,
        &x,
    );
    let best = report.best.expect("feasible");
    assert_eq!(best.family.arch, ArchChoice::Qr);
    assert_eq!(best.family.n, 64);
    assert_eq!(best.b_adc, 7);
    assert_eq!(best.b_adc, best.b_adc_mpc, "matches the MPC assignment");
    pin("opt_energy", best.energy_j, 7.305_828_721_750_138e-12, 1e-9);
    assert!(best.snr_t_db >= 21.5);
    // and the answer is a frontier point of its own domain
    let fr = frontier(&d, 1, &w, &x);
    assert!(fr.points.iter().any(|p| p.key() == best.key()));
}

#[test]
fn constrained_answers_always_lie_on_their_domain_frontier() {
    let (w, x) = uniform_stats();
    let d = Domain {
        archs: vec![ArchChoice::Qs, ArchChoice::Qr, ArchChoice::Cm],
        nodes: vec![TechNode::n65()],
        vwls: vec![0.6, 0.7, 0.8],
        cos: vec![1.0, 3.0, 9.0],
        ns: vec![64, 128, 256],
        bxs: vec![4, 6],
        bws: vec![4, 6],
        b_adcs: vec![3, 4, 5, 6, 7, 8, 9, 10],
    }
    .normalized()
    .unwrap();
    let fr = frontier(&d, 1, &w, &x);
    let cases: Vec<(Objective, Constraints)> = vec![
        (Objective::MinEnergy, Constraints::default()),
        (
            Objective::MinEnergy,
            Constraints {
                snr_t_min_db: Some(12.0),
                ..Constraints::default()
            },
        ),
        (
            Objective::MinEnergy,
            Constraints {
                snr_t_min_db: Some(20.0),
                delay_max_s: Some(3e-9),
                ..Constraints::default()
            },
        ),
        (
            Objective::MinDelay,
            Constraints {
                snr_t_min_db: Some(15.0),
                energy_max_j: Some(3e-11),
                ..Constraints::default()
            },
        ),
        (
            Objective::MaxSnr,
            Constraints {
                energy_max_j: Some(1e-11),
                ..Constraints::default()
            },
        ),
        (
            Objective::MaxSnr,
            Constraints {
                delay_max_s: Some(2e-9),
                ..Constraints::default()
            },
        ),
    ];
    for (objective, constraints) in cases {
        let report = optimize(&d, objective, &constraints, &w, &x);
        let best = report
            .best
            .unwrap_or_else(|| panic!("{objective:?} {constraints:?} infeasible"));
        assert!(
            fr.points.iter().any(|p| p.key() == best.key()),
            "{objective:?} answer {} off the frontier",
            best.label()
        );
        assert!(constraints.admits(&best));
    }
}

#[test]
fn crossover_reproduces_conclusion_3() {
    // Conclusion 3: QS-based architectures are preferred at low compute
    // SNR, QR-based at high. At N = 512 with Bx/Bw free to follow the
    // target (the paper's precision-assignment discipline) the flip
    // sits at 10 dB under the eq. (26) ADC model: QS is the cheaper
    // feasible design for every integer target 1..=9 dB, QR for every
    // target 10..=28 dB (QS is outright infeasible beyond 13 dB — its
    // SNR_a ceiling, the other half of the conclusion).
    let (w, x) = uniform_stats();
    let d = Domain {
        archs: vec![ArchChoice::Qs, ArchChoice::Qr],
        nodes: vec![TechNode::n65()],
        vwls: parse_grid_f64("0.55:0.9:0.05").unwrap(),
        cos: vec![0.5, 1.0, 2.0, 3.0, 6.0, 9.0],
        ns: vec![512],
        bxs: parse_grid_u32("1:8").unwrap(),
        bws: parse_grid_u32("1:8").unwrap(),
        b_adcs: parse_grid_u32("1:14").unwrap(),
    }
    .normalized()
    .unwrap();
    let targets: Vec<f64> = (1..=28).map(|t| t as f64).collect();
    let report = crossover(&d, &targets, &w, &x).unwrap();
    assert_eq!(report.crossover_snr_t_db, Some(10.0), "the flip target");
    for row in &report.rows {
        let t = row.target_snr_t_db;
        if t <= 9.0 {
            assert_eq!(row.preferred, Some(ArchChoice::Qs), "target {t} dB");
        } else {
            assert_eq!(row.preferred, Some(ArchChoice::Qr), "target {t} dB");
        }
        if t > 13.5 {
            assert!(row.qs.is_none(), "QS ceiling exceeded at {t} dB");
            assert!(row.qr.is_some(), "QR still feasible at {t} dB");
        }
    }
    assert!(report.qs_max_snr_t_db < report.qr_max_snr_t_db);
    assert!(report.qs_max_snr_t_db > 9.0 && report.qs_max_snr_t_db < 16.0);
    assert!(report.qr_max_snr_t_db > 25.0);
}

#[test]
fn pareto_cli_is_byte_identical_warm_vs_cold_and_across_procs() {
    let exe = env!("CARGO_BIN_EXE_imclim");
    let base = [
        "pareto", "--arch", "qs,qr", "--n", "32,64", "--b-adc", "4:6", "--vwl", "0.7", "--co",
        "3", "--validate", "--trials", "48", "--workers", "2",
    ];
    let tmp = |name: &str| {
        let dir = std::env::temp_dir().join(format!("imclim-opt-cli-{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    };
    let run = |out_dir: &std::path::Path, extra: &[&str]| {
        let out = std::process::Command::new(exe)
            .args(base)
            .args(extra)
            .arg("--out-dir")
            .arg(out_dir)
            .output()
            .unwrap();
        let err = String::from_utf8_lossy(&out.stderr).into_owned();
        assert!(out.status.success(), "pareto failed: {err}");
        std::fs::read(out_dir.join("pareto.csv")).unwrap()
    };
    let dir = tmp("cold");
    let cold = run(&dir, &[]);
    let warm = run(&dir, &[]);
    assert_eq!(cold, warm, "warm rerun is byte-identical");
    let procs_dir = tmp("procs");
    let sharded = run(&procs_dir, &["--procs", "3"]);
    assert_eq!(cold, sharded, "--procs 3 output matches --procs 1");
    // frontier CSV really is dominance-free: SNR_T strictly increases
    // along the energy-sorted rows (3-objective check is in-library;
    // with one delay profile per arch this is the CSV-level shadow)
    let text = String::from_utf8(cold).unwrap();
    assert!(text.lines().count() >= 2, "header + at least one row");
}
