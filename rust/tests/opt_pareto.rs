//! Design-space optimizer regression suite: golden four-objective
//! frontier pins at the 512-row reference configuration (including the
//! banked slice), optimizer/frontier consistency properties (no
//! dominated rows, axis-permutation and shard-count invariance, every
//! constrained answer on its domain frontier, MPC agreement),
//! brute-force frontier equality with the area objective, the QS-vs-QR
//! crossover of conclusion 3, and CLI-level warm-vs-cold /
//! multi-thread byte determinism of `imclim pareto` with `--banks`.

use imclim::engine::{parse_grid_f64, parse_grid_u32, parse_grid_usize};
use imclim::figures::uniform_stats;
use imclim::opt::{
    crossover, frontier, optimize, ArchChoice, Constraints, DesignPoint, Domain, Objective,
};
use imclim::tech::TechNode;

/// Relative-tolerance pin (same contract as golden_snr.rs).
fn pin(label: &str, actual: f64, golden: f64, rel: f64) {
    let err = ((actual - golden) / golden.abs().max(1e-300)).abs();
    assert!(
        err < rel,
        "{label}: actual {actual:.15e} vs golden {golden:.15e} (rel err {err:.2e})"
    );
}

/// The CLI's default search domain (the acceptance configuration):
/// `--arch qs,qr --n 64:512:64 --b-adc 4:10 --vwl 0.6:0.9:0.1 --co 3`.
fn acceptance_domain() -> Domain {
    Domain {
        archs: vec![ArchChoice::Qs, ArchChoice::Qr],
        nodes: vec![TechNode::n65()],
        vwls: parse_grid_f64("0.6:0.9:0.1").unwrap(),
        cos: parse_grid_f64("3").unwrap(),
        ns: parse_grid_usize("64:512:64").unwrap(),
        bxs: vec![6],
        bws: vec![6],
        b_adcs: parse_grid_u32("4:10").unwrap(),
        banks: vec![1],
    }
    .normalized()
    .unwrap()
}

/// Brute-force dominance filter over a full enumeration.
fn reference_frontier(points: &[DesignPoint]) -> Vec<&DesignPoint> {
    points
        .iter()
        .filter(|p| !points.iter().any(|q| q.dominates(p)))
        .collect()
}

#[test]
fn golden_frontier_at_512_row_reference() {
    // n = 512 restriction of the acceptance domain: the 512-row
    // reference configuration of golden_snr.rs, now under all four
    // objectives. Hand-derived outcome: the QR C_o = 3 fF column (one
    // point per B_ADC — energy, area and SNR_T all strictly grow with
    // bits) survives exactly as in the three-objective frontier, and
    // the V_WL = 0.6 QS column joins it on the area axis — QS arrays
    // carry no MOM caps, so despite collapsing to ~13.3 dB they are
    // the smallest designs at 512 rows and nothing dominates them.
    // Higher-V_WL QS families stay off the frontier (same area, more
    // energy, less SNR than the 0.6 V column).
    let (w, x) = uniform_stats();
    let d = Domain {
        ns: vec![512],
        ..acceptance_domain()
    }
    .normalized()
    .unwrap();
    let fr = frontier(&d, 1, &w, &x);
    assert_eq!(fr.points.len(), 14, "QR column + area-admitted QS column");

    let qr: Vec<_> = fr
        .points
        .iter()
        .filter(|p| p.family.arch == ArchChoice::Qr)
        .collect();
    assert_eq!(qr.len(), 7, "one QR frontier point per B_ADC in 4..=10");
    for (i, p) in qr.iter().enumerate() {
        assert_eq!(p.family.n, 512);
        assert_eq!(p.family.c_ff, Some(3.0));
        assert_eq!(p.b_adc, 4 + i as u32, "sorted by energy == by B_ADC");
        assert_eq!(p.b_adc_mpc, 7, "eq. (15) assignment at SNR_A ~22 dB");
        pin("qr512_snr_a", p.snr_a_total_db, 21.990_261_132_279_12, 1e-9);
    }
    // exact closed-form pins (hand-derived from Table III + eqs.
    // 11/14/25/26) — identical to the pre-area frontier values
    pin("b4_snr_t", qr[0].snr_t_db, 15.657_330_402_719_50, 1e-9);
    pin("b4_energy", qr[0].energy_j, 1.364_407_512_175_014e-11, 1e-9);
    pin("b4_delay_ns", qr[0].delay_ns(), 0.9, 1e-9);
    pin("b7_snr_t", qr[3].snr_t_db, 21.767_634_095_714_89, 1e-9);
    pin("b7_energy", qr[3].energy_j, 2.287_585_752_175_014e-11, 1e-9);
    pin("b10_snr_t", qr[6].snr_t_db, 21.982_172_187_853_56, 1e-9);
    pin("b10_energy", qr[6].energy_j, 5.003_099_311_217_504e-10, 1e-9);
    pin("b10_delay_ns", qr[6].delay_ns(), 1.5, 1e-9);
    // area pins for the same column (Table III geometry: cells + caps +
    // row ADCs + DACs)
    pin("b4_area", qr[0].area_mm2, 8.227_644e-3, 1e-9);
    pin("b10_area", qr[6].area_mm2, 9.876_534e-3, 1e-9);

    let qs: Vec<_> = fr
        .points
        .iter()
        .filter(|p| p.family.arch == ArchChoice::Qs)
        .collect();
    assert_eq!(qs.len(), 7, "the area-admitted QS column");
    for (i, p) in qs.iter().enumerate() {
        assert_eq!(p.family.v_wl, Some(0.6), "largest-headroom QS family");
        assert_eq!(p.b_adc, 4 + i as u32);
        assert!(
            p.area_mm2 < qr[0].area_mm2,
            "every QS frontier point undercuts the smallest QR area"
        );
    }
    pin("qs512_b4_snr_t", qs[0].snr_t_db, 11.689_223_773_254_469, 1e-9);
    pin("qs512_b4_energy", qs[0].energy_j, 2.213_145_746_292_378_4e-11, 1e-9);
    pin("qs512_b4_area", qs[0].area_mm2, 2.157_794e-3, 1e-9);
    pin("qs512_b8_area", qs[4].area_mm2, 2.609_054e-3, 1e-9);
}

#[test]
fn acceptance_frontier_matches_brute_force_with_no_dominated_row() {
    let (w, x) = uniform_stats();
    let d = acceptance_domain();
    let fr = frontier(&d, 1, &w, &x);
    // no reported point is dominated by any candidate in the domain
    let all = d.all_points(&w, &x);
    assert_eq!(all.len(), 280, "40 families x 7 B_ADC values");
    for p in &fr.points {
        assert!(
            !all.iter().any(|q| q.dominates(p)),
            "{} is dominated",
            p.label()
        );
    }
    // and the frontier is exactly the brute-force reference set
    let mut want = reference_frontier(&all);
    want.sort_by_key(|p| p.key());
    let mut got: Vec<&DesignPoint> = fr.points.iter().collect();
    got.sort_by_key(|p| p.key());
    assert_eq!(got.len(), want.len());
    for (g, r) in got.iter().zip(&want) {
        assert_eq!(g.key(), r.key());
        assert_eq!(g.energy_j.to_bits(), r.energy_j.to_bits());
        assert_eq!(g.snr_t_db.to_bits(), r.snr_t_db.to_bits());
        assert_eq!(g.delay_s.to_bits(), r.delay_s.to_bits());
        assert_eq!(g.area_mm2.to_bits(), r.area_mm2.to_bits());
    }
    // the cheapest frontier design: QR at the smallest array and B_ADC
    let first = &fr.points[0];
    assert_eq!(first.family.arch, ArchChoice::Qr);
    assert_eq!(first.family.n, 64);
    assert_eq!(first.b_adc, 4);
    pin("acc_min_energy", first.energy_j, 4.576_855_921_750_138e-12, 1e-9);
}

#[test]
fn golden_banked_frontier_slice_escapes_the_ceiling() {
    // The acceptance slice at n = 512 with --banks 1,2,4: banked QS
    // families join the four-objective frontier (their per-bank arrays
    // stay inside the headroom, and QS silicon remains smaller than
    // QR's cap-heavy arrays even 4x replicated), and the best banked
    // QS design clears the single-bank QS SNR ceiling by over 5 dB —
    // conclusion 4's escape, visible in the frontier itself.
    let (w, x) = uniform_stats();
    let d = Domain {
        ns: vec![512],
        banks: vec![1, 2, 4],
        ..acceptance_domain()
    }
    .normalized()
    .unwrap();
    let fr = frontier(&d, 1, &w, &x);
    assert_eq!(fr.points.len(), 28, "banked golden slice size");
    let banked_qs: Vec<_> = fr
        .points
        .iter()
        .filter(|p| p.family.arch == ArchChoice::Qs && p.family.banks > 1)
        .collect();
    assert_eq!(banked_qs.len(), 15, "banked QS designs on the frontier");
    let single_qs_best = fr
        .points
        .iter()
        .filter(|p| p.family.arch == ArchChoice::Qs && p.family.banks == 1)
        .map(|p| p.snr_t_db)
        .fold(f64::NEG_INFINITY, f64::max);
    let banked_qs_best = banked_qs
        .iter()
        .map(|p| p.snr_t_db)
        .fold(f64::NEG_INFINITY, f64::max);
    // (the single-bank B_ADC = 10 point is itself dominated by a
    // 2-bank design with fewer bits — banking beats bit-buying at the
    // ceiling — so the best surviving single-bank point is B_ADC = 9)
    pin("single_qs_ceiling", single_qs_best, 13.284_016_300_301_701, 1e-9);
    pin("banked_qs_best", banked_qs_best, 18.559_614_907_136_893, 1e-9);
    assert!(
        banked_qs_best > single_qs_best + 5.0,
        "banking escapes the SNR ceiling on the frontier: {banked_qs_best} vs {single_qs_best}"
    );
    // golden pins for one banked frontier point: V_WL = 0.6, 2 banks,
    // B_ADC = 4 (per-bank arrays of 256 rows)
    let p = banked_qs
        .iter()
        .find(|p| p.family.v_wl == Some(0.6) && p.family.banks == 2 && p.b_adc == 4)
        .expect("banked reference point on frontier");
    pin("banked2_b4_snr_t", p.snr_t_db, 11.702_731_094_624_25, 1e-9);
    pin("banked2_b4_energy", p.energy_j, 4.075_739_445_190_053_5e-11, 1e-9);
    pin("banked2_b4_delay_ns", p.delay_ns(), 2.45, 1e-9);
    pin("banked2_b4_area", p.area_mm2, 2.290_63e-3, 1e-9);
    // brute-force equality on the banked slice (the area objective and
    // the banks axis together, re-proving extractor exactness)
    let all = d.all_points(&w, &x);
    assert_eq!(all.len(), 105, "(4 QS + 1 QR families) x 3 banks x 7 B_ADC");
    let mut want = reference_frontier(&all);
    want.sort_by_key(|p| p.key());
    let mut got: Vec<&DesignPoint> = fr.points.iter().collect();
    got.sort_by_key(|p| p.key());
    assert_eq!(got.len(), want.len());
    for (g, r) in got.iter().zip(&want) {
        assert_eq!(g.key(), r.key());
        assert_eq!(g.energy_j.to_bits(), r.energy_j.to_bits());
        assert_eq!(g.area_mm2.to_bits(), r.area_mm2.to_bits());
    }
}

#[test]
fn frontier_invariant_under_axis_permutation_and_shards() {
    let (w, x) = uniform_stats();
    let canonical = Domain {
        archs: vec![ArchChoice::Qs, ArchChoice::Qr, ArchChoice::Cm],
        nodes: vec![TechNode::n65(), TechNode::n22()],
        vwls: vec![0.6, 0.7, 0.8],
        cos: vec![1.0, 3.0],
        ns: vec![64, 128],
        bxs: vec![4, 6],
        bws: vec![6],
        b_adcs: vec![4, 6, 8],
        banks: vec![1, 2],
    };
    let permuted = Domain {
        archs: vec![ArchChoice::Cm, ArchChoice::Qr, ArchChoice::Qs],
        nodes: vec![TechNode::n22(), TechNode::n65()],
        vwls: vec![0.8, 0.6, 0.7],
        cos: vec![3.0, 1.0],
        ns: vec![128, 64],
        bxs: vec![6, 4],
        bws: vec![6],
        b_adcs: vec![8, 4, 6],
        banks: vec![2, 1],
    };
    let base = frontier(&canonical.clone().normalized().unwrap(), 1, &w, &x);
    assert!(!base.points.is_empty());
    let perm = frontier(&permuted.normalized().unwrap(), 1, &w, &x);
    let same = |a: &DesignPoint, b: &DesignPoint| {
        a.key() == b.key()
            && a.energy_j.to_bits() == b.energy_j.to_bits()
            && a.snr_t_db.to_bits() == b.snr_t_db.to_bits()
            && a.delay_s.to_bits() == b.delay_s.to_bits()
            && a.area_mm2.to_bits() == b.area_mm2.to_bits()
    };
    assert_eq!(base.points.len(), perm.points.len(), "axis permutation");
    for (a, b) in base.points.iter().zip(&perm.points) {
        assert!(same(a, b), "{} vs {}", a.label(), b.label());
    }
    for shards in [2, 4, 9] {
        let sharded = frontier(&canonical.clone().normalized().unwrap(), shards, &w, &x);
        assert_eq!(base.points.len(), sharded.points.len(), "{shards} shards");
        for (a, b) in base.points.iter().zip(&sharded.points) {
            assert!(same(a, b), "{shards} shards: {} vs {}", a.label(), b.label());
        }
    }
}

#[test]
fn optimize_min_energy_sits_on_frontier_and_matches_mpc() {
    // Acceptance query: min energy subject to SNR_T >= 21.5 dB — the
    // 512-row reference's "SNR_A within 0.5 dB" operating point. The
    // smallest feasible B_ADC is then exactly the eq. (15) MPC
    // assignment, so the optimizer's bit choice must agree with MPC.
    let (w, x) = uniform_stats();
    let d = acceptance_domain();
    let report = optimize(
        &d,
        Objective::MinEnergy,
        &Constraints {
            snr_t_min_db: Some(21.5),
            ..Constraints::default()
        },
        &w,
        &x,
    );
    let best = report.best.expect("feasible");
    assert_eq!(best.family.arch, ArchChoice::Qr);
    assert_eq!(best.family.n, 64);
    assert_eq!(best.b_adc, 7);
    assert_eq!(best.b_adc, best.b_adc_mpc, "matches the MPC assignment");
    pin("opt_energy", best.energy_j, 7.305_828_721_750_138e-12, 1e-9);
    assert!(best.snr_t_db >= 21.5);
    // and the answer is a frontier point of its own domain
    let fr = frontier(&d, 1, &w, &x);
    assert!(fr.points.iter().any(|p| p.key() == best.key()));
}

#[test]
fn constrained_answers_always_lie_on_their_domain_frontier() {
    let (w, x) = uniform_stats();
    let d = Domain {
        archs: vec![ArchChoice::Qs, ArchChoice::Qr, ArchChoice::Cm],
        nodes: vec![TechNode::n65()],
        vwls: vec![0.6, 0.7, 0.8],
        cos: vec![1.0, 3.0, 9.0],
        ns: vec![64, 128, 256],
        bxs: vec![4, 6],
        bws: vec![4, 6],
        b_adcs: vec![3, 4, 5, 6, 7, 8, 9, 10],
        banks: vec![1, 2],
    }
    .normalized()
    .unwrap();
    let fr = frontier(&d, 1, &w, &x);
    let cases: Vec<(Objective, Constraints)> = vec![
        (Objective::MinEnergy, Constraints::default()),
        (
            Objective::MinArea,
            Constraints {
                snr_t_min_db: Some(15.0),
                ..Constraints::default()
            },
        ),
        (
            Objective::MinEnergy,
            Constraints {
                snr_t_min_db: Some(15.0),
                area_max_mm2: Some(2e-3),
                ..Constraints::default()
            },
        ),
        (
            Objective::MinEnergy,
            Constraints {
                snr_t_min_db: Some(12.0),
                ..Constraints::default()
            },
        ),
        (
            Objective::MinEnergy,
            Constraints {
                snr_t_min_db: Some(20.0),
                delay_max_s: Some(3e-9),
                ..Constraints::default()
            },
        ),
        (
            Objective::MinDelay,
            Constraints {
                snr_t_min_db: Some(15.0),
                energy_max_j: Some(3e-11),
                ..Constraints::default()
            },
        ),
        (
            Objective::MaxSnr,
            Constraints {
                energy_max_j: Some(1e-11),
                ..Constraints::default()
            },
        ),
        (
            Objective::MaxSnr,
            Constraints {
                delay_max_s: Some(2e-9),
                ..Constraints::default()
            },
        ),
    ];
    for (objective, constraints) in cases {
        let report = optimize(&d, objective, &constraints, &w, &x);
        let best = report
            .best
            .unwrap_or_else(|| panic!("{objective:?} {constraints:?} infeasible"));
        assert!(
            fr.points.iter().any(|p| p.key() == best.key()),
            "{objective:?} answer {} off the frontier",
            best.label()
        );
        assert!(constraints.admits(&best));
    }
}

#[test]
fn crossover_reproduces_conclusion_3() {
    // Conclusion 3: QS-based architectures are preferred at low compute
    // SNR, QR-based at high. At N = 512 with Bx/Bw free to follow the
    // target (the paper's precision-assignment discipline) the flip
    // sits at 10 dB under the eq. (26) ADC model: QS is the cheaper
    // feasible design for every integer target 1..=9 dB, QR for every
    // target 10..=28 dB (QS is outright infeasible beyond 13 dB — its
    // SNR_a ceiling, the other half of the conclusion).
    let (w, x) = uniform_stats();
    let d = Domain {
        archs: vec![ArchChoice::Qs, ArchChoice::Qr],
        nodes: vec![TechNode::n65()],
        vwls: parse_grid_f64("0.55:0.9:0.05").unwrap(),
        cos: vec![0.5, 1.0, 2.0, 3.0, 6.0, 9.0],
        ns: vec![512],
        bxs: parse_grid_u32("1:8").unwrap(),
        bws: parse_grid_u32("1:8").unwrap(),
        b_adcs: parse_grid_u32("1:14").unwrap(),
        banks: vec![1],
    }
    .normalized()
    .unwrap();
    let targets: Vec<f64> = (1..=28).map(|t| t as f64).collect();
    let report = crossover(&d, &targets, &w, &x).unwrap();
    assert_eq!(report.crossover_snr_t_db, Some(10.0), "the flip target");
    for row in &report.rows {
        let t = row.target_snr_t_db;
        if t <= 9.0 {
            assert_eq!(row.preferred, Some(ArchChoice::Qs), "target {t} dB");
        } else {
            assert_eq!(row.preferred, Some(ArchChoice::Qr), "target {t} dB");
        }
        if t > 13.5 {
            assert!(row.qs.is_none(), "QS ceiling exceeded at {t} dB");
            assert!(row.qr.is_some(), "QR still feasible at {t} dB");
        }
    }
    assert!(report.qs_max_snr_t_db < report.qr_max_snr_t_db);
    assert!(report.qs_max_snr_t_db > 9.0 && report.qs_max_snr_t_db < 16.0);
    assert!(report.qr_max_snr_t_db > 25.0);
}

#[test]
fn pareto_cli_is_byte_identical_warm_vs_cold_and_across_procs() {
    let exe = env!("CARGO_BIN_EXE_imclim");
    let base = [
        "pareto", "--arch", "qs,qr", "--n", "32,64", "--b-adc", "4:6", "--vwl", "0.7", "--co",
        "3", "--banks", "1,2", "--validate", "--trials", "48", "--workers", "2",
    ];
    let tmp = |name: &str| {
        let dir = std::env::temp_dir().join(format!("imclim-opt-cli-{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    };
    let run = |out_dir: &std::path::Path, extra: &[&str]| {
        let out = std::process::Command::new(exe)
            .args(base)
            .args(extra)
            .arg("--out-dir")
            .arg(out_dir)
            .output()
            .unwrap();
        let err = String::from_utf8_lossy(&out.stderr).into_owned();
        assert!(out.status.success(), "pareto failed: {err}");
        std::fs::read(out_dir.join("pareto.csv")).unwrap()
    };
    let dir = tmp("cold");
    let cold = run(&dir, &[]);
    let warm = run(&dir, &[]);
    assert_eq!(cold, warm, "warm rerun is byte-identical");
    let procs_dir = tmp("procs");
    let sharded = run(&procs_dir, &["--procs", "3"]);
    assert_eq!(cold, sharded, "--procs 3 output matches --procs 1");
    // the CSV carries the four-objective columns (banks + area) and is
    // non-degenerate; the in-library tests own the dominance checks
    let text = String::from_utf8(cold).unwrap();
    assert!(text.lines().count() >= 2, "header + at least one row");
    let header = text.lines().next().unwrap();
    assert!(header.contains("banks") && header.contains("area_mm2"));
}
