//! End-to-end integration tests over the native backend: regenerate the
//! paper's figures at reduced trial counts and assert the headline
//! qualitative claims (DESIGN.md §4) hold.

use imclim::arch::{AdcCriterion, CmArch, ImcArch, OpPoint, QrArch, QsArch};
use imclim::compute::{qr::QrModel, qs::QsModel};
use imclim::figures::{self, FigCtx};
use imclim::tech::TechNode;

fn ctx(tmp: &str) -> FigCtx {
    let dir = std::env::temp_dir().join(format!("imclim-test-{tmp}"));
    // start cold: a cache surviving from a previous test invocation would
    // mask simulator regressions behind bit-identical stale results
    let _ = std::fs::remove_dir_all(&dir);
    let mut c = FigCtx::native(dir);
    c.trials = 1024;
    c
}

#[test]
fn fig4a_mpc_flat_bgc_grows() {
    let s = figures::run("fig4a", &ctx("fig4a")).unwrap().remove(0);
    // MPC: 8 bits meet ~40 dB independent of N.
    assert!(s.check("mpc_at_8b_db").unwrap() >= 40.0);
    // BGC assigns 16-20+ bits growing with N (paper: 16..20 for the
    // plotted range; our range extends to 2^13).
    assert!(s.check("bgc_bits_min").unwrap() >= 16.0);
    assert!(s.check("bgc_bits_max").unwrap() > s.check("bgc_bits_min").unwrap());
    // closed form matches MC within 1 dB
    assert!(s.check("mpc_mc_err_max_db").unwrap() < 1.0);
}

#[test]
fn fig4b_sqnr_peaks_at_zeta_4() {
    let s = figures::run("fig4b", &ctx("fig4b")).unwrap().remove(0);
    let z = s.check("best_zeta").unwrap();
    assert!((3.0..=5.0).contains(&z), "{z}");
    assert!(s.check("max_e_s_gap_db").unwrap() < 1.0);
}

#[test]
fn fig12_adc_energy_shapes() {
    let s = figures::run("fig12", &ctx("fig12")).unwrap().remove(0);
    // QS-Arch: MPC ADC energy non-increasing with N.
    assert!(s.check("qs_mpc_growth").unwrap() <= 1.05);
    // QR/CM: BGC costs off-scale more than MPC at large N.
    assert!(s.check("qr_bgc_over_mpc").unwrap() > 10.0);
    assert!(s.check("cm_bgc_over_mpc").unwrap() > 10.0);
    // QR/CM MPC ADC energy grows with N.
    assert!(s.check("qr_mpc_growth").unwrap() > 2.0);
    assert!(s.check("cm_mpc_growth").unwrap() > 2.0);
}

#[test]
fn fig13_scaling_hurts_qs_not_qr() {
    let s = figures::run("fig13", &ctx("fig13")).unwrap().remove(0);
    let qs65 = s.check("qs_max_snr_65").unwrap();
    let qs7 = s.check("qs_max_snr_7").unwrap();
    assert!(qs65 > qs7, "QS max SNR_A must degrade with scaling: {qs65} vs {qs7}");
    // QR stays within ~2 dB of its 65 nm max at 7 nm (quantization-limited)
    let qr65 = s.check("qr_max_snr_65").unwrap();
    let qr7 = s.check("qr_max_snr_7").unwrap();
    assert!((qr65 - qr7).abs() < 3.0, "{qr65} {qr7}");
}

#[test]
fn table1_and_table2_render() {
    let s1 = figures::run("table1", &ctx("t1")).unwrap().remove(0);
    assert_eq!(s1.check("designs").unwrap(), 23.0);
    let s2 = figures::run("table2", &ctx("t2")).unwrap().remove(0);
    assert!(s2.rows >= 12);
}

#[test]
fn table3_e_vs_s_within_2db() {
    let mut c = ctx("t3");
    c.trials = 3000;
    let s = figures::run("table3", &c).unwrap().remove(0);
    assert!(
        s.check("max_e_s_gap_db").unwrap() < 2.0,
        "closed forms must track the simulator: {:?}",
        s.checks
    );
}

#[test]
fn banked_figure_shows_the_ceiling_escape() {
    // Conclusion bullet 4 through the figure driver: 8 banks rescue the
    // N = 512 DP by tens of dB in both the closed form and the
    // simulation, at a bounded area premium, and closed form tracks MC
    // on the plateau.
    let s = figures::run("banked", &ctx("banked")).unwrap().remove(0);
    assert!(s.check("escape_closed_db").unwrap() > 30.0);
    assert!(s.check("escape_sim_db").unwrap() > 25.0);
    assert!(s.check("max_e_s_gap_db").unwrap() < 1.5);
    let area_ratio = s.check("area_ratio_512_8").unwrap();
    assert!(
        area_ratio > 1.0 && area_ratio < 3.0,
        "banking multiplies ADCs and periphery, not cells: {area_ratio}"
    );
    assert!(s.check("energy_ratio_512_8").unwrap() > 1.0, "banking costs energy");
}

#[test]
fn qr_reaches_high_snr_qs_cannot() {
    // Conclusion bullet 3, the robust half: QR-based architectures are
    // the ones that can deliver high compute SNR — QS-Arch has a hard
    // SNR_a ceiling from V_t mismatch + headroom at any V_WL.
    //
    // (The "QS cheaper at low SNR" half reproduces only in the sub-10 dB
    // corner under the eq. (26) ADC model: the k1 = 100 fJ/conversion
    // floor times B_w*B_x conversions dominates QS-Arch's energy. See
    // EXPERIMENTS.md §Deviations.)
    let (w, x) = figures::uniform_stats();
    let op = OpPoint::new(128, 6, 6, 8);

    let qr_big = QrArch::new(QrModel::new(TechNode::n65(), 16.0));
    assert!(qr_big.noise(&op, &w, &x).snr_a_db() > 30.0);
    assert!(
        QrArch::new(QrModel::new(TechNode::n65(), 9.0))
            .noise(&op, &w, &x)
            .snr_a_db()
            > 28.0
    );
    let qs_best = (55..=95)
        .map(|v| {
            QsArch::new(QsModel::new(TechNode::n65(), v as f64 / 100.0))
                .noise(&op, &w, &x)
                .snr_a_db()
        })
        .fold(f64::MIN, f64::max);
    assert!(qs_best < 30.0, "QS-Arch capped below 30 dB at N=128: {qs_best}");

    // And the per-conversion accounting behind the deviation: QS-Arch
    // pays Bw*Bx ADC conversions per DP, QR-Arch only Bw.
    let qs = QsArch::new(QsModel::new(TechNode::n65(), 0.8));
    let qr = QrArch::new(QrModel::new(TechNode::n65(), 1.0));
    let e_qs_adc = qs.energy(&op, AdcCriterion::Mpc, &w, &x).adc;
    let e_qr_adc = qr.energy(&op, AdcCriterion::Mpc, &w, &x).adc;
    assert!(e_qs_adc > 2.0 * e_qr_adc, "{e_qs_adc} vs {e_qr_adc}");
}

#[test]
fn snr_t_bounded_by_snr_a_everywhere() {
    // Conclusion bullet 1 over a grid of operating points.
    let (w, x) = figures::uniform_stats();
    for n in [32usize, 128, 512] {
        for b_adc in [4u32, 8, 12] {
            let op = OpPoint::new(n, 6, 6, b_adc);
            for arch in [
                Box::new(QsArch::new(QsModel::new(TechNode::n65(), 0.7))) as Box<dyn ImcArch>,
                Box::new(QrArch::new(QrModel::new(TechNode::n65(), 3.0))),
                Box::new(CmArch::new(
                    QsModel::new(TechNode::n65(), 0.7),
                    QrModel::new(TechNode::n65(), 3.0),
                )),
            ] {
                let nb = arch.noise(&op, &w, &x);
                assert!(nb.snr_t_db(1e-6) <= nb.snr_a_db() + 1e-9);
                assert!(nb.snr_a_total_db() <= nb.snr_a_db() + 1e-9);
            }
        }
    }
}

#[test]
fn cm_single_conversion_beats_qs_adc_energy() {
    // Conclusion bullet 8: CM avoids the Bw*Bx ADC conversions.
    let (w, x) = figures::uniform_stats();
    let op = OpPoint::new(128, 6, 6, 8);
    let qs = QsArch::new(QsModel::new(TechNode::n65(), 0.8));
    let cm = CmArch::new(
        QsModel::new(TechNode::n65(), 0.8),
        QrModel::new(TechNode::n65(), 3.0),
    );
    let e_qs = qs.energy(&op, AdcCriterion::Mpc, &w, &x).adc;
    let e_cm = cm.energy(&op, AdcCriterion::Mpc, &w, &x).adc;
    assert!(e_cm < e_qs / 4.0, "{e_cm} vs {e_qs}");
}

#[test]
fn cli_sweep_and_assign_run() {
    use imclim::cli::args::Args;
    let args = Args::parse(
        "sweep --arch qr --co 3 --n 64 --trials 256"
            .split_whitespace()
            .map(str::to_string),
    );
    imclim::cli::run(&args).unwrap();
    let args = Args::parse(
        "assign --snr-a 30".split_whitespace().map(str::to_string),
    );
    imclim::cli::run(&args).unwrap();
}

#[test]
fn ablation_correlated_mismatch_costs_about_3db() {
    let mut c = ctx("abl");
    c.trials = 2048;
    let s = figures::run("ablation", &c).unwrap().remove(0);
    let drop = s.check("corr_mean_drop_db").unwrap();
    assert!((1.5..5.0).contains(&drop), "{drop}");
    // Changing the input distribution moves signal power (E[x^2]) and
    // bit statistics together, so SNR_a and SQNR_qiy shift by a similar
    // amount — the *ratios* of the noise decomposition are stable.
    let a = s.check("dist_snr_a_shift_db").unwrap();
    let q = s.check("dist_sqnr_qiy_shift_db").unwrap();
    assert!((a - q).abs() < 2.5, "snr_a shift {a} vs sqnr shift {q}");
}
