//! Observability acceptance: tracing never perturbs outputs (a traced
//! sweep's CSV and cache records are byte-identical to an untraced
//! twin, and the trace itself is a Perfetto-loadable Chrome trace with
//! the expected spans); `GET /metrics` serves Prometheus text
//! exposition whose counters move monotonically across scrapes; and
//! `GET /jobs/<id>/events` streams NDJSON progress over chunked
//! transfer-encoding — a cold job yields per-point events before its
//! terminal line, a warm resubmission yields exactly the terminal line.
//!
//! The in-process daemon tests serialize on one mutex for the same
//! reason `tests/serve.rs` does: metrics are process-global.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use imclim::cli::serve::{start, ServeHandle};
use imclim::registry::http::HttpEndpoint;
use imclim::util::json::Json;

/// Serializes the in-process daemon tests (shared global metrics).
static TEST_LOCK: Mutex<()> = Mutex::new(());

const GRID_POINTS: usize = 6; // arch qs × n {8,12,16} × b-adc {4,5}
const GRID_TRIALS: usize = 48;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("imclim-obs-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn sweep_body() -> &'static str {
    r#"{"cmd":"sweep","options":{"arch":"qs","n":"8,12,16","b-adc":"4,5",
        "trials":"48","workers":"2"}}"#
}

fn daemon(name: &str) -> (ServeHandle, HttpEndpoint, PathBuf) {
    let out_dir = tmp_dir(name);
    let handle = start("127.0.0.1:0", out_dir.clone(), 64).unwrap();
    let ep = HttpEndpoint::parse(&handle.base_url()).unwrap();
    (handle, ep, out_dir)
}

fn submit(ep: &HttpEndpoint, body: &str) -> u64 {
    let (status, bytes) = ep.post("jobs", body.as_bytes(), "application/json").unwrap();
    let json = Json::parse(&String::from_utf8_lossy(&bytes)).unwrap_or(Json::Null);
    assert_eq!(status, 202, "submission accepted: {json:?}");
    json.get("id").and_then(Json::as_usize).expect("job id") as u64
}

/// Poll a job until it reaches a terminal state; returns its status
/// JSON.
fn wait_job(ep: &HttpEndpoint, id: u64) -> Json {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (status, bytes) = ep.get_raw(&format!("jobs/{id}")).unwrap();
        assert_eq!(status, 200, "status poll for job {id}");
        let json = Json::parse(&String::from_utf8_lossy(&bytes)).unwrap();
        let state = json.get("state").and_then(|v| v.as_str()).unwrap().to_string();
        if matches!(state.as_str(), "done" | "failed" | "canceled") {
            return json;
        }
        assert!(Instant::now() < deadline, "job {id} stuck in '{state}'");
        std::thread::sleep(Duration::from_millis(10));
    }
}

// ---------------------------------------------------------------------
// Trace determinism
// ---------------------------------------------------------------------

/// Run the reference grid through the CLI binary into `dir` with extra
/// flags appended.
fn run_sweep(dir: &Path, extra: &[&str]) -> std::process::Output {
    std::process::Command::new(env!("CARGO_BIN_EXE_imclim"))
        .args([
            "sweep", "--arch", "qs", "--n", "8,12,16", "--b-adc", "4,5", "--trials", "48",
            "--workers", "2", "--out-dir",
        ])
        .arg(dir)
        .args(extra)
        .output()
        .unwrap()
}

/// Every regular file under `root`, keyed by relative path.
fn dir_files(root: &Path) -> BTreeMap<String, Vec<u8>> {
    let mut out = BTreeMap::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(d) = stack.pop() {
        for entry in std::fs::read_dir(&d).unwrap() {
            let p = entry.unwrap().path();
            if p.is_dir() {
                stack.push(p);
            } else {
                let rel = p.strip_prefix(root).unwrap().to_string_lossy().into_owned();
                out.insert(rel, std::fs::read(&p).unwrap());
            }
        }
    }
    out
}

#[test]
fn tracing_never_perturbs_outputs_and_the_trace_is_perfetto_loadable() {
    // Subprocesses, so no TEST_LOCK: each run has its own metrics and
    // its own sticky trace state.
    let traced = tmp_dir("trace-on");
    let plain = tmp_dir("trace-off");
    let trace_path = traced.join("trace.json");

    let out = run_sweep(&traced, &["--trace", trace_path.to_str().unwrap()]);
    assert!(out.status.success(), "traced: {}", String::from_utf8_lossy(&out.stderr));
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("trace: "),
        "the trace summary line prints"
    );
    let out = run_sweep(&plain, &[]);
    assert!(out.status.success(), "plain: {}", String::from_utf8_lossy(&out.stderr));

    // The hard invariant: tracing observes, never perturbs.
    assert_eq!(
        std::fs::read(traced.join("sweep.csv")).unwrap(),
        std::fs::read(plain.join("sweep.csv")).unwrap(),
        "sweep.csv must be byte-identical with and without --trace"
    );
    // the trace file lands next to sweep.csv, outside cache/, so the
    // cache trees compare cleanly
    assert_eq!(
        dir_files(&traced.join("cache")),
        dir_files(&plain.join("cache")),
        "cache records must be byte-identical with and without --trace"
    );

    // The trace is one JSON array (Chrome trace format, the layout
    // Perfetto's legacy loader accepts) of well-formed events.
    let text = std::fs::read_to_string(&trace_path).unwrap();
    let json = Json::parse(&text).unwrap_or_else(|e| panic!("trace is not valid JSON: {e}"));
    let events = json.as_arr().expect("chrome trace is a JSON array");
    assert!(!events.is_empty());

    let mut span_names = Vec::new();
    for ev in events {
        let ph = ev.get("ph").and_then(|v| v.as_str()).expect("every event has ph");
        match ph {
            "X" => {
                for field in ["name", "cat", "ts", "dur", "pid", "tid"] {
                    assert!(ev.get(field).is_some(), "complete event lacks {field}: {ev:?}");
                }
                span_names.push(ev.get("name").unwrap().as_str().unwrap().to_string());
            }
            "M" => assert!(ev.get("name").is_some(), "metadata event lacks name"),
            other => panic!("unexpected event phase {other:?}"),
        }
    }
    for required in ["grid_parse", "cache_probe", "mc_chunk", "csv_emit"] {
        assert!(
            span_names.iter().any(|n| n == required),
            "trace lacks a {required:?} span; saw {span_names:?}"
        );
    }
    // 6 points × 48 trials is a single chunk per point.
    let chunks = span_names.iter().filter(|n| *n == "mc_chunk").count();
    assert_eq!(chunks, GRID_POINTS, "one mc_chunk span per computed point");
}

// ---------------------------------------------------------------------
// Prometheus exposition
// ---------------------------------------------------------------------

fn scrape(ep: &HttpEndpoint) -> String {
    let (status, bytes) = ep.get_raw("metrics").unwrap();
    assert_eq!(status, 200, "/metrics answers 200");
    String::from_utf8(bytes).expect("exposition is UTF-8")
}

/// The value of an unlabeled sample line `name value`.
fn sample(text: &str, name: &str) -> f64 {
    text.lines()
        .find_map(|l| l.strip_prefix(name).and_then(|rest| rest.strip_prefix(' ')))
        .unwrap_or_else(|| panic!("exposition lacks sample {name:?}:\n{text}"))
        .trim()
        .parse()
        .unwrap_or_else(|e| panic!("sample {name:?} is not a number: {e}"))
}

#[test]
fn metrics_endpoint_serves_prometheus_text_with_monotone_counters() {
    let _guard = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (handle, ep, _out) = daemon("metrics");

    let first = scrape(&ep);
    // Text exposition format 0.0.4: HELP/TYPE comments then samples.
    for family in [
        ("imclim_cache_hits_total", "counter"),
        ("imclim_cache_misses_total", "counter"),
        ("imclim_trials_completed_total", "counter"),
        ("imclim_jobs_queued", "gauge"),
        ("imclim_jobs_running", "gauge"),
        ("imclim_cache_probe_seconds", "histogram"),
        ("imclim_mc_chunk_seconds", "histogram"),
    ] {
        let (name, kind) = family;
        assert!(first.contains(&format!("# HELP {name} ")), "HELP for {name}");
        assert!(first.contains(&format!("# TYPE {name} {kind}")), "TYPE for {name}");
    }
    // Histograms carry the full cumulative-bucket contract.
    assert!(first.contains("imclim_mc_chunk_seconds_bucket{le=\"+Inf\"}"));
    assert!(first.contains("imclim_mc_chunk_seconds_sum"));
    assert!(first.contains("imclim_mc_chunk_seconds_count"));

    // One cold job moves the counters by exactly the grid's work.
    let id = submit(&ep, sweep_body());
    let status = wait_job(&ep, id);
    assert_eq!(status.get("state").and_then(|v| v.as_str()), Some("done"));
    let second = scrape(&ep);

    let delta = |name: &str| sample(&second, name) - sample(&first, name);
    assert_eq!(delta("imclim_points_computed_total"), GRID_POINTS as f64);
    assert_eq!(delta("imclim_trials_completed_total"), (GRID_POINTS * GRID_TRIALS) as f64);
    assert!(delta("imclim_cache_probe_seconds_count") >= 1.0, "probe histogram observed");
    assert!(delta("imclim_mc_chunk_seconds_count") >= 1.0, "chunk histogram observed");
    for name in [
        "imclim_cache_hits_total",
        "imclim_cache_misses_total",
        "imclim_mc_errors_total",
        "imclim_trace_spans_dropped_total",
    ] {
        assert!(delta(name) >= 0.0, "counter {name} is monotone");
    }
    // +Inf bucket equals the count (cumulative buckets are complete).
    assert_eq!(
        sample(&second, "imclim_mc_chunk_seconds_bucket{le=\"+Inf\"}"),
        sample(&second, "imclim_mc_chunk_seconds_count"),
    );
    assert_eq!(sample(&second, "imclim_jobs_running"), 0.0, "sampled after completion");

    handle.shutdown();
}

// ---------------------------------------------------------------------
// Live progress streaming
// ---------------------------------------------------------------------

fn stream_events(ep: &HttpEndpoint, id: u64) -> Vec<Json> {
    let body = ep
        .get_stream(&format!("jobs/{id}/events"), |_| {})
        .unwrap_or_else(|e| panic!("streaming job {id} events: {e:?}"));
    let text = String::from_utf8(body).expect("NDJSON is UTF-8");
    text.lines()
        .map(|l| Json::parse(l).unwrap_or_else(|e| panic!("bad NDJSON line {l:?}: {e}")))
        .collect()
}

#[test]
fn job_events_stream_ndjson_ending_with_the_terminal_status() {
    let _guard = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (handle, ep, _out) = daemon("events");

    // Cold job: connect while it runs; the stream replays everything
    // logged so far and follows the job to its terminal line.
    let cold = submit(&ep, sweep_body());
    let events = stream_events(&ep, cold);
    let kinds: Vec<&str> = events
        .iter()
        .map(|j| j.get("kind").and_then(|v| v.as_str()).expect("every event has a kind"))
        .collect();
    assert!(kinds.contains(&"mc_start"), "{kinds:?}");
    assert!(
        kinds.iter().filter(|k| **k == "point").count() >= GRID_POINTS,
        "one event per computed point: {kinds:?}"
    );
    assert_eq!(kinds.last(), Some(&"terminal"), "{kinds:?}");
    let terminal = events.last().unwrap();
    assert_eq!(terminal.get("state").and_then(|v| v.as_str()), Some("done"));
    assert_eq!(terminal.get("points_computed").and_then(Json::as_usize), Some(GRID_POINTS));
    assert!(terminal.get("duration_ms").is_some(), "{terminal:?}");

    // The status JSON carries the new lifecycle timestamps.
    let status = wait_job(&ep, cold);
    for field in ["queued_at_ms", "started_at_ms", "finished_at_ms", "duration_ms"] {
        assert!(status.get(field).is_some(), "status lacks {field}: {status:?}");
    }

    // Warm resubmission: nothing computes, so the stream is exactly the
    // terminal line (the scheduler never starts).
    let warm = submit(&ep, sweep_body());
    wait_job(&ep, warm);
    let events = stream_events(&ep, warm);
    assert_eq!(events.len(), 1, "warm job streams only its terminal event: {events:?}");
    let terminal = &events[0];
    assert_eq!(terminal.get("kind").and_then(|v| v.as_str()), Some("terminal"));
    assert_eq!(terminal.get("state").and_then(|v| v.as_str()), Some("done"));
    assert_eq!(terminal.get("cache_hits").and_then(Json::as_usize), Some(GRID_POINTS));
    assert_eq!(terminal.get("points_computed").and_then(Json::as_usize), Some(0));

    // Unknown job: the events route 404s rather than hanging.
    let (st, _) = ep.get_raw("jobs/9999/events").unwrap();
    assert_eq!(st, 404);

    handle.shutdown();
}
