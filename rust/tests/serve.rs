//! Sweep-as-a-service acceptance: the `imclim serve` daemon accepts
//! sweep jobs from concurrent HTTP clients and answers with CSVs that
//! are byte-identical to the same query run through the CLI; warm
//! submissions recompute nothing (zero Monte-Carlo); a mid-run shutdown
//! drains gracefully (the in-flight job completes, queued jobs are
//! canceled) without corrupting the shared cache; and a SIGTERM'd
//! daemon subprocess exits 0.
//!
//! Per-job metrics are process-global counters sampled around each
//! job, so the in-process daemon tests serialize on one mutex — they
//! pass under the default test harness and under `--test-threads 1`
//! alike.

use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use imclim::cli::serve::{start, ServeHandle};
use imclim::registry::http::HttpEndpoint;
use imclim::util::json::Json;

/// Serializes the in-process daemon tests (shared global metrics).
static TEST_LOCK: Mutex<()> = Mutex::new(());

const GRID_POINTS: usize = 6; // arch qs × n {8,12,16} × b-adc {4,5}
const GRID_TRIALS: usize = 48;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("imclim-serve-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn sweep_body() -> &'static str {
    r#"{"cmd":"sweep","options":{"arch":"qs","n":"8,12,16","b-adc":"4,5",
        "trials":"48","workers":"2"}}"#
}

/// The same grid through the CLI binary; returns sweep.csv bytes.
fn cli_reference_csv(dir: &Path) -> Vec<u8> {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_imclim"))
        .args([
            "sweep", "--arch", "qs", "--n", "8,12,16", "--b-adc", "4,5", "--trials", "48",
            "--workers", "2", "--out-dir",
        ])
        .arg(dir)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "reference sweep failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    std::fs::read(dir.join("sweep.csv")).unwrap()
}

fn daemon(name: &str) -> (ServeHandle, HttpEndpoint, PathBuf) {
    let out_dir = tmp_dir(name);
    let handle = start("127.0.0.1:0", out_dir.clone(), 64).unwrap();
    let ep = HttpEndpoint::parse(&handle.base_url()).unwrap();
    (handle, ep, out_dir)
}

fn post_json(ep: &HttpEndpoint, rel: &str, body: &str) -> (u16, Json) {
    let (status, bytes) = ep.post(rel, body.as_bytes(), "application/json").unwrap();
    let text = String::from_utf8_lossy(&bytes);
    let json = Json::parse(&text).unwrap_or(Json::Null);
    (status, json)
}

fn submit(ep: &HttpEndpoint, body: &str) -> u64 {
    let (status, json) = post_json(ep, "jobs", body);
    assert_eq!(status, 202, "submission accepted: {json:?}");
    json.get("id").and_then(Json::as_usize).expect("job id") as u64
}

/// Poll a job until it reaches a terminal state; returns its status
/// JSON.
fn wait_job(ep: &HttpEndpoint, id: u64) -> Json {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (status, bytes) = ep.get_raw(&format!("jobs/{id}")).unwrap();
        assert_eq!(status, 200, "status poll for job {id}");
        let json = Json::parse(&String::from_utf8_lossy(&bytes)).unwrap();
        let state = json.get("state").and_then(|v| v.as_str()).unwrap().to_string();
        if matches!(state.as_str(), "done" | "failed" | "canceled") {
            return json;
        }
        assert!(Instant::now() < deadline, "job {id} stuck in '{state}'");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn metric(json: &Json, name: &str) -> usize {
    json.get(name)
        .and_then(Json::as_usize)
        .unwrap_or_else(|| panic!("status JSON lacks '{name}': {json:?}"))
}

#[test]
fn concurrent_clients_get_cli_identical_csvs_and_warm_jobs_recompute_nothing() {
    let _guard = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let reference = cli_reference_csv(&tmp_dir("cli-ref"));
    let (handle, ep, _out) = daemon("concurrent");

    // health first: the daemon answers before any job exists
    let (st, body) = ep.get_raw("healthz").unwrap();
    assert_eq!((st, body.as_slice()), (200, &b"ok\n"[..]));

    // four clients race the same grid; the sequential executor computes
    // it once, every later job is served entirely from the shared cache
    let statuses: Vec<Json> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let ep = ep.clone();
                scope.spawn(move || {
                    let id = submit(&ep, sweep_body());
                    let status = wait_job(&ep, id);
                    let (st, csv) = ep.get_raw(&format!("jobs/{id}/result")).unwrap();
                    assert_eq!(st, 200, "result for job {id}");
                    (status, csv)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                let (status, csv) = h.join().unwrap();
                assert_eq!(csv, reference, "served CSV must be byte-identical to the CLI run");
                status
            })
            .collect()
    });

    let computed: Vec<usize> = statuses.iter().map(|j| metric(j, "points_computed")).collect();
    assert_eq!(
        computed.iter().sum::<usize>(),
        GRID_POINTS,
        "the grid is computed exactly once across all jobs: {computed:?}"
    );
    assert_eq!(
        computed.iter().filter(|&&c| c == 0).count(),
        3,
        "every repeat job is fully warm (zero Monte-Carlo): {computed:?}"
    );
    for j in &statuses {
        assert_eq!(j.get("state").and_then(|v| v.as_str()), Some("done"));
        let (hits, misses) = (metric(j, "cache_hits"), metric(j, "cache_misses"));
        assert_eq!(hits + misses, GRID_POINTS, "every point accounted for");
    }
    let trials: usize = statuses.iter().map(|j| metric(j, "trials_completed")).sum();
    assert_eq!(trials, GRID_POINTS * GRID_TRIALS, "trial accounting matches the one cold job");

    // process-wide observability
    let (st, bytes) = ep.get_raw("stats").unwrap();
    assert_eq!(st, 200);
    let stats = Json::parse(&String::from_utf8_lossy(&bytes)).unwrap();
    assert!(metric(&stats, "cache_hits") >= 3 * GRID_POINTS, "{stats:?}");
    let jobs = stats.get("jobs").expect("per-state job counts");
    assert_eq!(metric(jobs, "done"), 4, "{stats:?}");
    assert_eq!(metric(&stats, "jobs_in_flight"), 0, "{stats:?}");
    assert_eq!(stats.get("draining"), Some(&Json::Bool(false)));

    handle.shutdown();
}

#[test]
fn bad_submissions_and_unknown_jobs_answer_4xx_not_5xx() {
    let _guard = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (handle, ep, _out) = daemon("errors");

    for (body, needle) in [
        (r#"{"cmd":"figure"}"#, "unsupported cmd"),
        (r#"{"options":{}}"#, "missing 'cmd'"),
        (r#"{"cmd":"sweep","options":{"out-dir":"/x"}}"#, "reserved"),
        (r#"{"cmd":"sweep","options":{"n":16}}"#, "must be a string"),
        ("not json", "bad JSON"),
    ] {
        let (status, json) = post_json(&ep, "jobs", body);
        assert_eq!(status, 400, "{body}");
        let err = json.get("error").and_then(|v| v.as_str()).unwrap_or("");
        assert!(err.contains(needle), "{err:?} should mention {needle:?}");
    }

    let (status, _) = ep.get_raw("jobs/9999").unwrap();
    assert_eq!(status, 404, "unknown job id");
    let (status, _) = ep.get_raw("jobs/9999/result").unwrap();
    assert_eq!(status, 404, "unknown job result");
    let (status, _) = ep.get_raw("jobs/not-a-number").unwrap();
    assert_eq!(status, 400, "non-numeric job id");
    let (status, _) = ep.get_raw("no/such/route").unwrap();
    assert_eq!(status, 404);
    let (status, _) = ep.post("healthz", b"", "text/plain").unwrap();
    assert_eq!(status, 404, "POST to a GET-only route");

    // a job that fails (bad grid) reports 'failed' with its error, and
    // its result endpoint answers 409, not a broken 200
    let id = submit(&ep, r#"{"cmd":"sweep","options":{"n":"garbage"}}"#);
    let status = wait_job(&ep, id);
    assert_eq!(status.get("state").and_then(|v| v.as_str()), Some("failed"));
    assert!(status.get("error").is_some(), "{status:?}");
    let (st, bytes) = ep.get_raw(&format!("jobs/{id}/result")).unwrap();
    assert_eq!(st, 409, "no result for a failed job");
    assert!(String::from_utf8_lossy(&bytes).contains("failed"));

    handle.shutdown();
}

#[test]
fn mid_run_shutdown_drains_without_corrupting_the_shared_cache() {
    let _guard = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let reference = cli_reference_csv(&tmp_dir("drain-cli-ref"));
    let (handle, ep, out_dir) = daemon("drain");

    // fill the queue behind one job, then pull the plug mid-run
    let first = submit(&ep, sweep_body());
    let rest: Vec<u64> = (0..3).map(|_| submit(&ep, sweep_body())).collect();

    // make sure the first job has actually been claimed before draining
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (st, bytes) = ep.get_raw(&format!("jobs/{first}")).unwrap();
        assert_eq!(st, 200);
        let json = Json::parse(&String::from_utf8_lossy(&bytes)).unwrap();
        let state = json.get("state").and_then(|v| v.as_str()).unwrap().to_string();
        if state != "queued" {
            break;
        }
        assert!(Instant::now() < deadline, "first job never started");
        std::thread::sleep(Duration::from_millis(5));
    }
    let (st, body) = ep.post("shutdown", b"", "text/plain").unwrap();
    assert_eq!((st, body.as_slice()), (200, &b"draining\n"[..]));
    handle.wait();

    // the in-flight job completed and its CSV matches the CLI twin
    let first_csv = out_dir.join("jobs").join(first.to_string()).join("sweep.csv");
    assert_eq!(
        std::fs::read(&first_csv).unwrap(),
        reference,
        "the in-flight job drains to a complete, CLI-identical CSV"
    );
    // queued jobs either ran to completion before the drain hit the
    // queue or were canceled — but a canceled job never leaves a
    // partial CSV behind
    for id in rest {
        let csv = out_dir.join("jobs").join(id.to_string()).join("sweep.csv");
        if csv.exists() {
            assert_eq!(std::fs::read(&csv).unwrap(), reference, "job {id}");
        }
    }

    // cache integrity after the drain: a CLI run over the daemon's
    // shared cache is fully warm and byte-identical
    let warm_dir = tmp_dir("drain-warm");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_imclim"))
        .args([
            "sweep", "--arch", "qs", "--n", "8,12,16", "--b-adc", "4,5", "--trials", "48",
            "--workers", "2", "--cache-dir",
        ])
        .arg(out_dir.join("cache"))
        .arg("--out-dir")
        .arg(&warm_dir)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("(6 cache hits, 0 computed)"),
        "the drained daemon's cache serves the whole grid: {stdout}"
    );
    assert_eq!(std::fs::read(warm_dir.join("sweep.csv")).unwrap(), reference);
}

/// Write raw bytes at the daemon and return its full response text —
/// the hostile-client path that never goes through our HTTP client.
fn raw_request(ep: &HttpEndpoint, payload: &[u8]) -> String {
    use std::io::{Read, Write};
    let mut conn = std::net::TcpStream::connect((ep.host.as_str(), ep.port)).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    conn.write_all(payload).unwrap();
    let _ = conn.shutdown(std::net::Shutdown::Write);
    let mut buf = Vec::new();
    conn.read_to_end(&mut buf).unwrap();
    String::from_utf8_lossy(&buf).into_owned()
}

#[test]
fn hostile_requests_get_4xx_answers_with_bounded_memory() {
    use imclim::registry::http::{MAX_BODY_BYTES, MAX_HEADER_BYTES};

    let _guard = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (handle, ep, _out) = daemon("hostile");

    // headers that never end stop buffering at the cap -> 431
    let mut endless = b"GET /healthz HTTP/1.1\r\nX-Pad: ".to_vec();
    endless.resize(MAX_HEADER_BYTES + 128, b'a');
    let reply = raw_request(&ep, &endless);
    assert!(reply.starts_with("HTTP/1.1 431 "), "{reply}");

    // a malformed Content-Length used to silently parse as an empty
    // body; now it is a 400
    let reply = raw_request(
        &ep,
        b"POST /jobs HTTP/1.1\r\nContent-Length: banana\r\n\r\n{\"cmd\":\"sweep\"}",
    );
    assert!(reply.starts_with("HTTP/1.1 400 "), "{reply}");
    assert!(reply.contains("Content-Length"), "{reply}");

    // chunked request bodies would be misparsed as raw bytes -> 411
    let reply = raw_request(
        &ep,
        b"POST /jobs HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n4\r\nWiki\r\n0\r\n\r\n",
    );
    assert!(reply.starts_with("HTTP/1.1 411 "), "{reply}");

    // a declared body over the cap is refused before any of it is
    // buffered -> 413 (note: no body bytes are sent at all)
    let huge = format!(
        "POST /jobs HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
        MAX_BODY_BYTES + 1
    );
    let reply = raw_request(&ep, huge.as_bytes());
    assert!(reply.starts_with("HTTP/1.1 413 "), "{reply}");

    // well-formed traffic on the same daemon still works afterwards
    let (st, body) = ep.get_raw("healthz").unwrap();
    assert_eq!((st, body.as_slice()), (200, &b"ok\n"[..]));

    handle.shutdown();
}

#[cfg(unix)]
#[test]
fn sigterm_drains_the_daemon_subprocess_and_it_exits_zero() {
    use std::io::{BufRead, BufReader};

    // no lock needed: the daemon is a subprocess with its own metrics
    let out_dir = tmp_dir("sigterm");
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_imclim"))
        .args(["serve", "--addr", "127.0.0.1:0", "--out-dir"])
        .arg(&out_dir)
        .stdout(std::process::Stdio::piped())
        .spawn()
        .unwrap();

    // the readiness line carries the port-0 assignment
    let stdout = child.stdout.take().unwrap();
    let mut lines = BufReader::new(stdout).lines();
    let url = loop {
        let line = lines.next().expect("daemon exited before listening").unwrap();
        if let Some(rest) = line.strip_prefix("imclim serve: listening on ") {
            break rest.to_string();
        }
    };
    let ep = HttpEndpoint::parse(&url).unwrap();
    let (st, body) = ep.get_raw("healthz").unwrap();
    assert_eq!((st, body.as_slice()), (200, &b"ok\n"[..]));
    let id = submit(&ep, sweep_body());
    wait_job(&ep, id);

    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    const SIGTERM: i32 = 15;
    assert_eq!(unsafe { kill(child.id() as i32, SIGTERM) }, 0);
    let status = child.wait().unwrap();
    assert!(status.success(), "SIGTERM must drain to exit 0: {status:?}");
}
