//! Three-way agreement: closed form (Table III) vs native Rust MC vs the
//! AOT JAX/Pallas artifacts through PJRT — the central validation that
//! the three independent implementations describe the same physics.
//! Uses the *_small artifacts (16 trials x 64 cells) for speed.
//!
//! The banked cross-check at the bottom needs no artifacts: it drives
//! the native simulator through `engine::Engine` (cache and all) and
//! proves the Sec. VI ceiling-escape claim numerically.

use std::path::PathBuf;

use imclim::arch::{pvec, Banked, ImcArch, OpPoint};
use imclim::arch::{CmArch, QrArch, QsArch};
use imclim::compute::{qr::QrModel, qs::QsModel};
use imclim::coordinator::{run_point, Backend, PjrtService, SweepOptions, SweepPoint};
use imclim::engine::Engine;
use imclim::mc::ArchKind;
use imclim::quant::SignalStats;
use imclim::tech::TechNode;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn stats() -> (SignalStats, SignalStats) {
    (
        SignalStats::uniform_signed(1.0),
        SignalStats::uniform_unsigned(1.0),
    )
}

/// |a - b| in dB terms must be below `tol_db`.
fn assert_db_close(a: f64, b: f64, tol_db: f64, what: &str) {
    assert!(
        (a - b).abs() < tol_db,
        "{what}: {a:.2} dB vs {b:.2} dB (tol {tol_db})"
    );
}

#[test]
fn three_way_agreement_all_architectures() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let service = PjrtService::spawn(dir, 4);
    let handle = service.handle();
    let (w, x) = stats();
    let trials = 2048;

    struct Case {
        name: &'static str,
        kind: ArchKind,
        params: [f64; pvec::P],
        closed_snr_a_db: f64,
        /// closed-form tolerance (looser where Table III approximates)
        tol_closed: f64,
    }
    let mut cases = Vec::new();

    // QS-Arch at N=48 (inside the plateau for the small artifact's N_max=64)
    {
        let arch = QsArch::new(QsModel::new(TechNode::n65(), 0.8));
        let op = OpPoint::new(48, 6, 6, 14);
        cases.push(Case {
            name: "qs",
            kind: ArchKind::Qs,
            params: arch.pjrt_params(&op, &w, &x),
            closed_snr_a_db: arch.noise(&op, &w, &x).snr_a_total_db(),
            tol_closed: 1.0,
        });
    }
    // QR-Arch at C_o = 1 fF
    {
        let arch = QrArch::new(QrModel::new(TechNode::n65(), 1.0));
        let op = OpPoint::new(64, 6, 7, 14);
        cases.push(Case {
            name: "qr",
            kind: ArchKind::Qr,
            params: arch.pjrt_params(&op, &w, &x),
            closed_snr_a_db: arch.noise(&op, &w, &x).snr_a_total_db(),
            tol_closed: 1.2,
        });
    }
    // CM at B_w = 6
    {
        let arch = CmArch::new(
            QsModel::new(TechNode::n65(), 0.8),
            QrModel::new(TechNode::n65(), 3.0),
        );
        let op = OpPoint::new(64, 6, 6, 14);
        cases.push(Case {
            name: "cm",
            kind: ArchKind::Cm,
            params: arch.pjrt_params(&op, &w, &x),
            closed_snr_a_db: arch.noise(&op, &w, &x).snr_a_total_db(),
            tol_closed: 1.2,
        });
    }

    for c in cases {
        let point = SweepPoint::new(format!("xcheck/{}", c.name), c.kind, c.params)
            .with_trials(trials)
            .with_seed(0x5EED);
        let native = run_point(&point, &Backend::Native).unwrap();
        let pjrt = run_point(
            &point,
            &Backend::Pjrt {
                handle: handle.clone(),
                suffix: "_small",
            },
        )
        .unwrap();

        // native MC vs PJRT/Pallas MC: same physics, independent code +
        // RNGs; agreement within MC ensemble error (~0.6 dB at 2k trials)
        assert_db_close(
            native.snr_a_total_db,
            pjrt.snr_a_total_db,
            1.0,
            &format!("{} native-vs-pjrt SNR_A", c.name),
        );
        assert_db_close(
            native.sqnr_qiy_db,
            pjrt.sqnr_qiy_db,
            1.0,
            &format!("{} native-vs-pjrt SQNR_qiy", c.name),
        );
        // closed form vs both simulators
        assert_db_close(
            c.closed_snr_a_db,
            native.snr_a_total_db,
            c.tol_closed,
            &format!("{} closed-vs-native SNR_A", c.name),
        );
        assert_db_close(
            c.closed_snr_a_db,
            pjrt.snr_a_total_db,
            c.tol_closed + 0.5,
            &format!("{} closed-vs-pjrt SNR_A", c.name),
        );
    }
}

/// Differential test (no artifacts needed): banked closed forms vs the
/// native Monte-Carlo, executed *through the engine* so the banked
/// parameter vectors exercise the real cache/scheduler path. Banks in
/// {2, 4} at N = 512 and 1024 — on-plateau points agree within the MC
/// ensemble error, and the banked designs clear ceilings their
/// single-bank versions collapse under (conclusion 4).
#[test]
fn banked_closed_form_matches_engine_mc_and_escapes_ceiling() {
    let (w, x) = stats();
    struct Case {
        label: &'static str,
        v_wl: f64,
        n: usize,
        banks: usize,
        /// single-bank SNR_A must sit at least this far below banked
        /// (0.0: banking is a no-op on the plateau, the control case)
        min_escape_db: f64,
    }
    let cases = [
        Case {
            label: "b2/n512",
            v_wl: 0.6,
            n: 512,
            banks: 2,
            min_escape_db: 0.0,
        },
        Case {
            label: "b2/n1024",
            v_wl: 0.6,
            n: 1024,
            banks: 2,
            min_escape_db: 25.0,
        },
        Case {
            label: "b4/n512",
            v_wl: 0.8,
            n: 512,
            banks: 4,
            min_escape_db: 30.0,
        },
    ];

    let dir = std::env::temp_dir().join("imclim-banked-xcheck");
    let _ = std::fs::remove_dir_all(&dir);
    let engine = Engine::new(
        Backend::Native,
        SweepOptions {
            workers: 4,
            verbose: false,
        },
    )
    .with_cache(dir.clone());

    let mut points = Vec::new();
    let mut closed = Vec::new();
    for c in &cases {
        let inner = QsArch::new(QsModel::new(TechNode::n65(), c.v_wl));
        let banked = Banked::new(Box::new(inner), c.banks);
        let op = OpPoint::new(c.n, 6, 6, 14).with_banks(c.banks);
        let banked_db = banked.noise(&op, &w, &x).snr_a_total_db();
        let single_db = inner.noise(&op, &w, &x).snr_a_total_db();
        assert!(
            banked_db - single_db >= c.min_escape_db,
            "{}: closed-form escape {banked_db} vs {single_db}",
            c.label
        );
        closed.push(banked_db);
        points.push(
            SweepPoint::new(
                format!("xcheck-banked/{}", c.label),
                ArchKind::Qs,
                banked.pjrt_params(&op, &w, &x),
            )
            .with_trials(2048)
            .with_seed(0xBA2C),
        );
    }
    let (results, stats_cold) = engine.run_with_stats(points.clone());
    assert_eq!(stats_cold.errors, 0, "banked points run natively");
    for ((c, closed_db), r) in cases.iter().zip(&closed).zip(&results) {
        assert_db_close(
            *closed_db,
            r.measured.snr_a_total_db,
            1.2,
            &format!("{} closed-vs-engine-MC banked SNR_A", c.label),
        );
    }
    // warm rerun: banked records hit the cache bit-exactly
    let (warm, stats_warm) = engine.run_with_stats(points);
    assert_eq!(stats_warm.hits, cases.len(), "banked cache keys round-trip");
    assert_eq!(stats_warm.misses, 0);
    for (a, b) in results.iter().zip(&warm) {
        assert_eq!(
            a.measured.snr_a_total_db.to_bits(),
            b.measured.snr_a_total_db.to_bits()
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pjrt_snr_t_saturates_with_adc_bits() {
    // Fig. 9(b) behaviour through the PJRT path.
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let service = PjrtService::spawn(dir, 4);
    let handle = service.handle();
    let (w, x) = stats();
    let arch = QsArch::new(QsModel::new(TechNode::n65(), 0.8));

    let snr_t = |b_adc: u32| {
        let op = OpPoint::new(48, 6, 6, b_adc);
        let point = SweepPoint::new(
            format!("sat/{b_adc}"),
            ArchKind::Qs,
            arch.pjrt_params(&op, &w, &x),
        )
        .with_trials(1024)
        .with_seed(77);
        run_point(
            &point,
            &Backend::Pjrt {
                handle: handle.clone(),
                suffix: "_small",
            },
        )
        .unwrap()
    };
    let low = snr_t(2);
    let mid = snr_t(5);
    let high = snr_t(9);
    assert!(low.snr_t_db < mid.snr_t_db);
    assert!(mid.snr_t_db <= high.snr_t_db + 0.3);
    // at 9 bits the ADC no longer limits: SNR_T ~ SNR_A
    assert!((high.snr_t_db - high.snr_a_total_db).abs() < 0.7);
}
