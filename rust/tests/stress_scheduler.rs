//! Concurrency stress tests for the lock-free sweep scheduler: many
//! workers racing over many tiny jobs must lose nothing, duplicate
//! nothing, keep submission order in the results, and produce
//! bit-identical measurements regardless of worker count or repetition.

use std::collections::HashSet;

use imclim::arch::pvec;
use imclim::coordinator::{run_sweep, Backend, SweepOptions, SweepPoint};
use imclim::mc::ArchKind;

/// A deliberately tiny job: few rows, few trials — the scheduling
/// overhead dominates, maximizing contention on the claim counter.
fn tiny_point(i: usize) -> SweepPoint {
    let mut p = [0.0; pvec::P];
    p[pvec::IDX_N_ACTIVE] = 4.0 + (i % 3) as f64;
    p[pvec::IDX_BX] = 4.0;
    p[pvec::IDX_BW] = 4.0;
    p[pvec::IDX_B_ADC] = 6.0;
    p[pvec::QS_IDX_SIGMA_D] = 0.1;
    p[pvec::QS_IDX_K_H] = 40.0;
    p[pvec::QS_IDX_V_C] = 40.0;
    SweepPoint::new(format!("stress/{i}"), ArchKind::Qs, p)
        .with_trials(8)
        .with_seed(i as u64)
}

fn run(n: usize, workers: usize) -> Vec<imclim::coordinator::SweepResult> {
    let points: Vec<SweepPoint> = (0..n).map(tiny_point).collect();
    run_sweep(
        points,
        Backend::Native,
        SweepOptions {
            workers,
            verbose: false,
        },
    )
}

#[test]
fn many_workers_tiny_jobs_lose_and_duplicate_nothing() {
    let n = 400;
    let results = run(n, 16);
    assert_eq!(results.len(), n, "no lost points");
    let mut ids = HashSet::new();
    for (i, r) in results.iter().enumerate() {
        assert_eq!(r.index, i, "submission order preserved");
        assert_eq!(r.id, format!("stress/{i}"));
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(!r.cached);
        assert_eq!(r.measured.trials, 8, "trial count met");
        assert!(ids.insert(r.id.clone()), "no duplicated point: {}", r.id);
    }
    assert_eq!(ids.len(), n);
}

#[test]
fn results_are_bit_identical_across_worker_counts() {
    let n = 120;
    let baseline = run(n, 1);
    for workers in [2usize, 3, 5, 8, 16] {
        let racy = run(n, workers);
        assert_eq!(racy.len(), baseline.len());
        for (a, b) in baseline.iter().zip(&racy) {
            assert_eq!(a.index, b.index, "workers={workers}");
            assert_eq!(a.id, b.id, "workers={workers}");
            assert_eq!(
                a.measured.snr_t_db.to_bits(),
                b.measured.snr_t_db.to_bits(),
                "workers={workers}, point {}",
                a.id
            );
            assert_eq!(
                a.measured.sigma_yo2.to_bits(),
                b.measured.sigma_yo2.to_bits(),
                "workers={workers}, point {}",
                a.id
            );
        }
    }
}

#[test]
fn repeated_runs_are_bit_identical() {
    let a = run(60, 8);
    let b = run(60, 8);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.measured.snr_a_db.to_bits(), y.measured.snr_a_db.to_bits());
        assert_eq!(x.measured.snr_t_db.to_bits(), y.measured.snr_t_db.to_bits());
    }
}

#[test]
fn extreme_oversubscription_single_point() {
    // 16 workers, 1 job: 15 workers must exit cleanly without claiming.
    let results = run(1, 16);
    assert_eq!(results.len(), 1);
    assert_eq!(results[0].index, 0);
    assert!(results[0].error.is_none());
}

#[test]
fn worker_count_zero_is_clamped_not_deadlocked() {
    // SweepOptions{workers: 0} must still make progress (clamped to 1).
    let results = run(5, 0);
    assert_eq!(results.len(), 5);
    for (i, r) in results.iter().enumerate() {
        assert_eq!(r.index, i);
    }
}
