//! Cache robustness: shard-directory merge is byte-identical to a
//! single-process run (engine-level and end-to-end through the CLI's
//! `--procs` orchestration), corrupt/truncated records degrade to
//! recompute, and GC respects `--max-bytes` while never evicting
//! records newer than `--max-age`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, SystemTime};

use imclim::arch::pvec;
use imclim::coordinator::{Backend, SweepOptions, SweepPoint};
use imclim::engine::{cache_key, gc, merge_cache_dirs, scan_records, Engine, GcOptions};
use imclim::figures::{self, FigCtx};
use imclim::mc::ArchKind;

fn qs_point(id: &str, n: usize, seed: u64) -> SweepPoint {
    let mut p = [0.0; pvec::P];
    p[pvec::IDX_N_ACTIVE] = n as f64;
    p[pvec::IDX_BX] = 5.0;
    p[pvec::IDX_BW] = 5.0;
    p[pvec::IDX_B_ADC] = 7.0;
    p[pvec::QS_IDX_SIGMA_D] = 0.1;
    p[pvec::QS_IDX_K_H] = 50.0;
    p[pvec::QS_IDX_V_C] = 50.0;
    SweepPoint::new(id, ArchKind::Qs, p)
        .with_trials(96)
        .with_seed(seed)
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("imclim-merge-gc-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn engine(dir: &Path) -> Engine {
    Engine::new(
        Backend::Native,
        SweepOptions {
            workers: 2,
            verbose: false,
        },
    )
    .with_cache(dir.to_path_buf())
}

/// Every file in a directory, name -> bytes (non-recursive).
fn dir_bytes(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    let mut out = BTreeMap::new();
    for entry in std::fs::read_dir(dir).unwrap().flatten() {
        if entry.path().is_file() {
            let name = entry.file_name().to_string_lossy().into_owned();
            out.insert(name, std::fs::read(entry.path()).unwrap());
        }
    }
    out
}

fn set_age(path: &Path, secs: u64) {
    let f = std::fs::OpenOptions::new().write(true).open(path).unwrap();
    f.set_modified(SystemTime::now() - Duration::from_secs(secs))
        .unwrap();
}

#[test]
fn merged_shard_dirs_are_byte_identical_to_single_run() {
    let points: Vec<SweepPoint> = (0..8)
        .map(|i| qs_point(&format!("m/{i}"), 16 + 4 * i, i as u64))
        .collect();

    let single = tmp_dir("merge-single");
    engine(&single).run(points.clone());

    // two "shards" computing the even/odd halves in their own dirs
    let shard0 = tmp_dir("merge-shard0");
    let shard1 = tmp_dir("merge-shard1");
    let evens: Vec<SweepPoint> = points.iter().step_by(2).cloned().collect();
    let odds: Vec<SweepPoint> = points.iter().skip(1).step_by(2).cloned().collect();
    engine(&shard0).run(evens);
    engine(&shard1).run(odds);

    let merged = tmp_dir("merge-merged");
    let report = merge_cache_dirs(&merged, &[shard0, shard1]).unwrap();
    assert_eq!(report.copied, 8);
    assert_eq!(report.identical, 0);
    assert!(report.collisions.is_empty());
    assert_eq!(report.backends, vec![Backend::Native.cache_id()]);

    let a = dir_bytes(&single);
    let b = dir_bytes(&merged);
    assert_eq!(
        a.keys().collect::<Vec<_>>(),
        b.keys().collect::<Vec<_>>(),
        "same file set (records + manifest)"
    );
    for (name, bytes) in &a {
        assert_eq!(bytes, &b[name], "byte-identical: {name}");
    }
    // and the merged cache actually serves: a re-run computes nothing
    let (_, stats) = engine(&merged).run_with_stats(points);
    assert_eq!(stats.misses, 0);
    assert_eq!(stats.hits, 8);
}

#[test]
fn merge_detects_collisions_and_keeps_destination() {
    let dst = tmp_dir("collide-dst");
    let src = tmp_dir("collide-src");
    std::fs::create_dir_all(&dst).unwrap();
    std::fs::create_dir_all(&src).unwrap();
    std::fs::write(dst.join("kboth.json"), b"{\"v\": 1}").unwrap();
    std::fs::write(src.join("kboth.json"), b"{\"v\": 2}").unwrap();
    std::fs::write(src.join("konly.json"), b"{\"v\": 3}").unwrap();
    std::fs::write(src.join("ksame.json"), b"{\"v\": 4}").unwrap();
    std::fs::write(dst.join("ksame.json"), b"{\"v\": 4}").unwrap();

    let report = merge_cache_dirs(&dst, &[src]).unwrap();
    assert_eq!(report.copied, 1, "only the new key is copied");
    assert_eq!(report.identical, 1);
    assert_eq!(report.collisions, vec!["kboth".to_string()]);
    // destination payload wins on collision
    assert_eq!(std::fs::read(dst.join("kboth.json")).unwrap(), b"{\"v\": 1}");
    assert_eq!(std::fs::read(dst.join("konly.json")).unwrap(), b"{\"v\": 3}");
}

#[test]
fn truncated_record_degrades_to_recompute() {
    let dir = tmp_dir("truncate");
    let e = engine(&dir);
    let mk = || vec![qs_point("t/0", 24, 9)];
    let (cold, s0) = e.run_with_stats(mk());
    assert_eq!(s0.misses, 1);

    let record = dir.join(format!("{}.json", cache_key(&mk()[0], &Backend::Native.cache_id())));
    let bytes = std::fs::read(&record).unwrap();
    for keep in [bytes.len() / 2, 1, 0] {
        std::fs::write(&record, &bytes[..keep]).unwrap();
        let (again, stats) = e.run_with_stats(mk());
        assert_eq!(stats.misses, 1, "truncated to {keep} bytes is a miss");
        assert!(again[0].error.is_none());
        assert_eq!(
            cold[0].measured.snr_t_db.to_bits(),
            again[0].measured.snr_t_db.to_bits(),
            "recompute is bit-identical"
        );
    }
}

/// Build a cache with 4 records aged (oldest -> newest) 400s, 300s,
/// 200s, 100s; returns (dir, keys oldest-first).
fn aged_cache(name: &str) -> (PathBuf, Vec<String>) {
    let dir = tmp_dir(name);
    let points: Vec<SweepPoint> = (0..4)
        .map(|i| qs_point(&format!("gc/{i}"), 16 + 4 * i, i as u64))
        .collect();
    engine(&dir).run(points);
    let mut records = scan_records(&dir).unwrap();
    assert_eq!(records.len(), 4);
    // stable assignment: sort by key, then age deterministically
    records.sort_by(|a, b| a.key.cmp(&b.key));
    for (i, r) in records.iter().enumerate() {
        set_age(&r.path, 400 - 100 * i as u64);
    }
    let keys: Vec<String> = records.iter().map(|r| r.key.clone()).collect();
    (dir, keys)
}

#[test]
fn gc_max_age_expires_only_older_records() {
    let (dir, keys) = aged_cache("gc-age");
    let report = gc(
        &dir,
        &GcOptions {
            max_bytes: None,
            max_age: Some(Duration::from_secs(250)),
            dry_run: false,
        },
    )
    .unwrap();
    // ages 400 and 300 expire; 200 and 100 survive
    assert_eq!(report.scanned, 4);
    assert_eq!(report.evicted, 2);
    let mut expect = vec![keys[0].clone(), keys[1].clone()];
    expect.sort();
    assert_eq!(report.evicted_keys, expect);
    let survivors = scan_records(&dir).unwrap();
    assert_eq!(survivors.len(), 2);
    // manifest no longer lists evicted keys, still lists survivors
    let manifest = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    assert!(!manifest.contains(&keys[0]));
    assert!(!manifest.contains(&keys[1]));
    assert!(manifest.contains(&keys[2]));
    assert!(manifest.contains(&keys[3]));
}

#[test]
fn gc_max_bytes_evicts_least_recently_used_first() {
    let (dir, keys) = aged_cache("gc-bytes");
    let records = scan_records(&dir).unwrap(); // oldest first
    let budget: u64 = records[2].bytes + records[3].bytes;
    let report = gc(
        &dir,
        &GcOptions {
            max_bytes: Some(budget),
            max_age: None,
            dry_run: false,
        },
    )
    .unwrap();
    assert_eq!(report.evicted, 2, "evicts until it fits");
    assert!(report.bytes_after <= budget);
    let mut expect = vec![keys[0].clone(), keys[1].clone()];
    expect.sort();
    assert_eq!(report.evicted_keys, expect, "oldest two go first");
    let survivor_keys: Vec<String> = scan_records(&dir)
        .unwrap()
        .into_iter()
        .map(|r| r.key)
        .collect();
    assert!(survivor_keys.contains(&keys[2]));
    assert!(survivor_keys.contains(&keys[3]));
}

#[test]
fn gc_never_evicts_records_newer_than_max_age() {
    let (dir, _) = aged_cache("gc-protect");
    // zero byte budget, but every record is newer than max-age: all
    // records are protected, so nothing may be evicted.
    let report = gc(
        &dir,
        &GcOptions {
            max_bytes: Some(0),
            max_age: Some(Duration::from_secs(3600)),
            dry_run: false,
        },
    )
    .unwrap();
    assert_eq!(report.evicted, 0, "max-age protects newer records");
    assert_eq!(report.bytes_after, report.bytes_before);
    assert_eq!(scan_records(&dir).unwrap().len(), 4);
}

#[test]
fn gc_dry_run_deletes_nothing() {
    let (dir, _) = aged_cache("gc-dry");
    let report = gc(
        &dir,
        &GcOptions {
            max_bytes: Some(0),
            max_age: None,
            dry_run: true,
        },
    )
    .unwrap();
    assert_eq!(report.evicted, 4, "dry run reports the plan");
    assert_eq!(scan_records(&dir).unwrap().len(), 4, "nothing deleted");
}

#[test]
fn fig4a_rerun_serves_all_monte_carlo_from_cache() {
    let dir = tmp_dir("fig4a-warm");
    let mut ctx = FigCtx::native(dir.clone());
    ctx.trials = 64; // bespoke MC floors at 2000 trials internally
    let s1 = figures::run("fig4a", &ctx).unwrap().remove(0);
    assert!(s1.check("mc_points").unwrap() > 0.0);
    assert_eq!(s1.check("mc_cached_points").unwrap(), 0.0, "cold run");
    let csv1 = std::fs::read(dir.join("fig4a.csv")).unwrap();

    let s2 = figures::run("fig4a", &ctx).unwrap().remove(0);
    assert_eq!(
        s2.check("mc_cached_points").unwrap(),
        s2.check("mc_points").unwrap(),
        "warm run performs zero Monte-Carlo"
    );
    let csv2 = std::fs::read(dir.join("fig4a.csv")).unwrap();
    assert_eq!(csv1, csv2, "warm CSV is byte-identical");
}

#[test]
fn sharded_cli_sweep_is_byte_identical_to_single_process() {
    let exe = env!("CARGO_BIN_EXE_imclim");
    let base = [
        "sweep", "--arch", "qs", "--n", "8,12,16,20", "--b-adc", "4,5", "--trials", "48",
        "--workers", "2",
    ];
    let single = tmp_dir("cli-single");
    let sharded = tmp_dir("cli-sharded");

    let out = std::process::Command::new(exe)
        .args(base)
        .arg("--out-dir")
        .arg(&single)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "single-process sweep failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    let out = std::process::Command::new(exe)
        .args(base)
        .args(["--procs", "4"])
        .arg("--out-dir")
        .arg(&sharded)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "sharded sweep failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    let csv_a = std::fs::read(single.join("sweep.csv")).unwrap();
    let csv_b = std::fs::read(sharded.join("sweep.csv")).unwrap();
    assert_eq!(csv_a, csv_b, "sweep.csv byte-identical across k=4 shards");

    let cache_a = dir_bytes(&single.join("cache"));
    let cache_b = dir_bytes(&sharded.join("cache"));
    assert_eq!(
        cache_a.keys().collect::<Vec<_>>(),
        cache_b.keys().collect::<Vec<_>>(),
        "cache dirs hold the same records"
    );
    for (name, bytes) in &cache_a {
        assert_eq!(bytes, &cache_b[name], "cache record {name} differs");
    }
    assert!(
        !sharded.join("shard-0").exists(),
        "shard work dirs are cleaned up after a clean merge"
    );
}
