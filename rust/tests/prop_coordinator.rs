//! Property-based tests on coordinator invariants (routing, batching,
//! aggregation), using the in-repo `prop` framework.

use imclim::arch::pvec;
use imclim::coordinator::{run_sweep, Backend, SweepOptions, SweepPoint};
use imclim::mc::{ArchKind, InputDist, McOutput, SnrAccumulator};
use imclim::prop::{check, gens, Config};
use imclim::util::rng::Pcg64;

fn random_point(rng: &mut Pcg64, idx: usize) -> SweepPoint {
    let kind = match rng.below(3) {
        0 => ArchKind::Qs,
        1 => ArchKind::Qr,
        _ => ArchKind::Cm,
    };
    let mut p = [0.0; pvec::P];
    p[pvec::IDX_N_ACTIVE] = gens::usize_in(8, 96)(rng) as f64;
    p[pvec::IDX_BX] = gens::u32_in(2, 8)(rng) as f64;
    p[pvec::IDX_BW] = gens::u32_in(2, 8)(rng) as f64;
    p[pvec::IDX_B_ADC] = gens::u32_in(3, 12)(rng) as f64;
    match kind {
        ArchKind::Qs => {
            p[pvec::QS_IDX_SIGMA_D] = rng.uniform_in(0.0, 0.25);
            p[pvec::QS_IDX_K_H] = rng.uniform_in(20.0, 200.0);
            p[pvec::QS_IDX_V_C] = rng.uniform_in(10.0, 100.0);
        }
        ArchKind::Qr => {
            p[pvec::QR_IDX_SIGMA_C] = rng.uniform_in(0.0, 0.1);
            p[pvec::QR_IDX_SIGMA_THETA] = rng.uniform_in(0.0, 0.01);
            p[pvec::QR_IDX_V_C] = rng.uniform_in(0.2, 1.0);
        }
        ArchKind::Cm => {
            p[pvec::CM_IDX_SIGMA_D] = rng.uniform_in(0.0, 0.25);
            p[pvec::CM_IDX_W_H] = rng.uniform_in(0.3, 2.0);
            p[pvec::CM_IDX_V_C] = rng.uniform_in(0.05, 0.8);
        }
    }
    SweepPoint::new(format!("prop/{idx}/{kind:?}"), kind, p)
        .with_trials(gens::usize_in(32, 200)(rng))
        .with_seed(rng.next_u64())
}

#[test]
fn every_point_gets_exactly_one_result_any_worker_count() {
    check(
        Config { cases: 12, seed: 0xAB },
        |rng: &mut Pcg64| {
            let n = gens::usize_in(1, 12)(rng);
            let workers = gens::usize_in(1, 9)(rng);
            let points: Vec<SweepPoint> =
                (0..n).map(|i| random_point(rng, i)).collect();
            (points, workers)
        },
        |(points, workers)| {
            let ids: Vec<String> = points.iter().map(|p| p.id.clone()).collect();
            let res = run_sweep(
                points.clone(),
                Backend::Native,
                SweepOptions {
                    workers: *workers,
                    verbose: false,
                },
            );
            if res.len() != points.len() {
                return Err(format!("{} results for {} points", res.len(), points.len()));
            }
            for (i, r) in res.iter().enumerate() {
                if r.index != i || r.id != ids[i] {
                    return Err(format!("result {i} mismatched: {} at {}", r.id, r.index));
                }
                if let Some(e) = &r.error {
                    return Err(format!("unexpected error: {e}"));
                }
                if r.measured.trials != points[i].trials as u64 {
                    return Err(format!(
                        "trial count {} != requested {}",
                        r.measured.trials, points[i].trials
                    ));
                }
            }
            Ok(())
        },
    )
    .unwrap();
}

#[test]
fn results_deterministic_and_worker_count_independent() {
    check(
        Config { cases: 8, seed: 0xCD },
        |rng: &mut Pcg64| {
            (0..gens::usize_in(2, 8)(rng))
                .map(|i| random_point(rng, i))
                .collect::<Vec<_>>()
        },
        |points| {
            let run = |workers| {
                run_sweep(
                    points.clone(),
                    Backend::Native,
                    SweepOptions {
                        workers,
                        verbose: false,
                    },
                )
            };
            let a = run(1);
            let b = run(7);
            for (x, y) in a.iter().zip(&b) {
                if x.measured.snr_t_db.to_bits() != y.measured.snr_t_db.to_bits() {
                    return Err(format!(
                        "{}: {} != {}",
                        x.id, x.measured.snr_t_db, y.measured.snr_t_db
                    ));
                }
            }
            Ok(())
        },
    )
    .unwrap();
}

#[test]
fn chunked_aggregation_is_order_insensitive() {
    // The accumulator used by the PJRT batcher must give (nearly) the
    // same statistics regardless of chunk arrival order.
    check(
        Config { cases: 20, seed: 0xEF },
        |rng: &mut Pcg64| {
            let chunks: Vec<McOutput> = (0..gens::usize_in(2, 6)(rng))
                .map(|_| {
                    let len = gens::usize_in(8, 64)(rng);
                    let mut o = McOutput::default();
                    for _ in 0..len {
                        let yi = rng.normal();
                        o.push(
                            yi,
                            yi + 0.1 * rng.normal(),
                            yi + 0.2 * rng.normal(),
                            yi + 0.3 * rng.normal(),
                        );
                    }
                    o
                })
                .collect();
            chunks
        },
        |chunks| {
            let mut fwd = SnrAccumulator::new();
            for c in chunks {
                fwd.push_chunk(c);
            }
            let mut rev = SnrAccumulator::new();
            for c in chunks.iter().rev() {
                rev.push_chunk(c);
            }
            let (a, b) = (fwd.finalize(), rev.finalize());
            let close = |p: f64, q: f64| (p - q).abs() < 1e-9 || (p - q).abs() / p.abs().max(1e-12) < 1e-9;
            if !close(a.snr_t_db, b.snr_t_db) || a.trials != b.trials {
                return Err(format!("{} vs {}", a.snr_t_db, b.snr_t_db));
            }
            Ok(())
        },
    )
    .unwrap();
}

#[test]
fn native_mc_respects_zero_noise_invariant() {
    // For any op point with all noise off and wide ADC, SNR_T ==
    // SQNR_qiy (no analog or output noise).
    check(
        Config { cases: 16, seed: 0x11 },
        |rng: &mut Pcg64| {
            let n = gens::usize_in(8, 128)(rng);
            let bx = gens::u32_in(2, 8)(rng);
            let bw = gens::u32_in(2, 8)(rng);
            (n, bx, bw, rng.next_u64())
        },
        |&(n, bx, bw, seed)| {
            let mut p = [0.0; pvec::P];
            p[pvec::IDX_N_ACTIVE] = n as f64;
            p[pvec::IDX_BX] = bx as f64;
            p[pvec::IDX_BW] = bw as f64;
            p[pvec::IDX_B_ADC] = 16.0;
            p[pvec::QS_IDX_K_H] = 1e9;
            p[pvec::QS_IDX_V_C] = 4.0 * n as f64;
            let out = imclim::mc::simulate(ArchKind::Qs, &p, 200, seed, InputDist::Uniform);
            let m = imclim::mc::measure(&out);
            if (m.snr_t_db - m.sqnr_qiy_db).abs() > 0.2 {
                return Err(format!(
                    "SNR_T {} != SQNR_qiy {}",
                    m.snr_t_db, m.sqnr_qiy_db
                ));
            }
            Ok(())
        },
    )
    .unwrap();
}

#[test]
fn mc_snr_improves_with_smaller_sigma() {
    // Monotonicity: less mismatch can't hurt SNR_a (statistically).
    check(
        Config { cases: 10, seed: 0x22 },
        |rng: &mut Pcg64| (gens::f64_in(0.05, 0.3)(rng), rng.next_u64()),
        |&(sigma, seed)| {
            let mk = |s: f64| {
                let mut p = [0.0; pvec::P];
                p[pvec::IDX_N_ACTIVE] = 64.0;
                p[pvec::IDX_BX] = 6.0;
                p[pvec::IDX_BW] = 6.0;
                p[pvec::IDX_B_ADC] = 14.0;
                p[pvec::QS_IDX_SIGMA_D] = s;
                p[pvec::QS_IDX_K_H] = 1e9;
                p[pvec::QS_IDX_V_C] = 200.0;
                let out = imclim::mc::simulate(ArchKind::Qs, &p, 1500, seed, InputDist::Uniform);
                imclim::mc::measure(&out).snr_a_db
            };
            let hi = mk(sigma);
            let lo = mk(sigma / 2.0);
            if lo < hi + 1.0 {
                return Err(format!("halving sigma {sigma}: {hi} -> {lo}"));
            }
            Ok(())
        },
    )
    .unwrap();
}
