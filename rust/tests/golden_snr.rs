//! Golden regression tests for the closed-form SNR/physics stack.
//!
//! Every value below was hand-derived from the paper's equations
//! (Table II constants, eqs. 1/5/8-15/18-24) at the 512-row reference
//! configuration — 65 nm, V_WL = 0.8 V, C_o = 3 fF, B_x = B_w = 6,
//! uniform signal statistics — and cross-checked against the paper's
//! quoted figures (sigma_D ~ 0.107, k_h ~ 44, SQNR_qiy(7,7) = 41 dB,
//! MPC(8 b, zeta 4) ~ 40.8 dB). They pin the *exact* closed forms: a
//! physics regression that moves any of these quantities fails loudly
//! instead of silently shifting every figure.

use imclim::arch::{
    binomial_clip_moment, AdcCriterion, Banked, CmArch, ImcArch, OpPoint, QrArch, QsArch,
};
use imclim::compute::is_model::IsModel;
use imclim::compute::qr::QrModel;
use imclim::compute::qs::QsModel;
use imclim::quant::criteria::{bgc_sqnr_db, gaussian_clip_stats, mpc_sqnr_db};
use imclim::quant::{
    dp_signal_variance, qiy_variance, sqnr_qiy_db, sqnr_qy_db, SignalStats,
};
use imclim::snr::{compose, snr_a_total_db};
use imclim::tech::TechNode;

/// Relative-tolerance pin with a readable failure message.
fn pin(label: &str, actual: f64, golden: f64, rel: f64) {
    let err = ((actual - golden) / golden.abs().max(1e-300)).abs();
    assert!(
        err < rel,
        "{label}: actual {actual:.15e} vs golden {golden:.15e} (rel err {err:.2e})"
    );
}

fn uni() -> (SignalStats, SignalStats) {
    (
        SignalStats::uniform_signed(1.0),
        SignalStats::uniform_unsigned(1.0),
    )
}

#[test]
fn golden_signal_statistics_and_input_quantization() {
    let (w, x) = uni();
    // PAR (eq. 8 prelude): 10 log10(3/4) and 10 log10(3).
    pin("par_x", x.par_db_unsigned(), -1.249_387_366_082_999_3, 1e-12);
    pin("par_w", w.par_db_signed(), 4.771_212_547_196_624, 1e-12);
    // eq. (5): sigma_yo^2 = N sigma_w^2 E[x^2] = 512/9.
    pin(
        "dp_signal_var",
        dp_signal_variance(512, &w, &x),
        56.888_888_888_888_886,
        1e-12,
    );
    // eq. (5): sigma_qiy^2 at B_x = B_w = 6.
    pin(
        "qiy_var",
        qiy_variance(512, 6, 6, &w, &x),
        0.017_361_111_111_111_11,
        1e-12,
    );
    // eq. (8): SQNR_qiy = 35.154... dB (= 41.2 dB at 7/7 minus 6.02).
    pin(
        "sqnr_qiy",
        sqnr_qiy_db(512, 6, 6, &w, &x),
        35.154_499_349_597_18,
        1e-12,
    );
    // eq. (9): full-range 8-bit output quantizer at N = 512.
    pin(
        "sqnr_qy",
        sqnr_qy_db(512, 8, &w, &x),
        22.315_475_209_128_06,
        1e-12,
    );
}

#[test]
fn golden_snr_composition() {
    // eq. (10): 30 dB analog + 39 dB input quantization -> 29.485 dB.
    pin(
        "snr_a_total",
        snr_a_total_db(30.0, 39.0),
        29.485_030_579_747_7,
        1e-12,
    );
    pin("compose", compose(&[100.0, 100.0]), 50.0, 1e-12);
}

#[test]
fn golden_output_precision_criteria() {
    let (w, x) = uni();
    // eq. (14): MPC at B_y = 8, zeta = 4 (paper: ~40.8 dB).
    pin("mpc_8_4", mpc_sqnr_db(8, 4.0), 40.546_022_393_519_33, 1e-9);
    // eq. (13): BGC at B_x = B_w = 7, N = 512.
    pin(
        "bgc_7_7_512",
        bgc_sqnr_db(7, 7, 512, &w, &x),
        112.620_874_428_644_68,
        1e-12,
    );
    // clipping probability at 4 sigma stays in the paper's ~1e-4 band
    let (pc, _) = gaussian_clip_stats(4.0);
    assert!(pc > 1e-5 && pc < 1e-3, "{pc}");
}

#[test]
fn golden_qs_compute_model() {
    // 65 nm, V_WL = 0.8 V, 512-row bit-line (Table II + eqs. 16-21).
    let qs = QsModel::new(TechNode::n65(), 0.8);
    pin("qs_sigma_d", qs.sigma_d(), 0.1071, 1e-12);
    pin("qs_cell_current", qs.cell_current(), 6.341_937_011_421_957e-5, 1e-9);
    pin("qs_t_rf", qs.t_rf(), 1.285_714_285_714_285_5e-11, 1e-12);
    pin(
        "qs_delta_v_unit",
        qs.delta_v_unit(),
        0.020_468_685_592_420_075,
        1e-9,
    );
    pin("qs_k_h", qs.k_h(), 43.969_604_004_923_81, 1e-9);
    pin("qs_sigma_t_rel", qs.sigma_t_rel(), 0.023, 1e-12);
    pin(
        "qs_sigma_theta_counts",
        qs.sigma_theta_counts(512),
        0.012_356_423_142_755_441,
        1e-9,
    );
}

#[test]
fn golden_is_compute_model() {
    let is = IsModel::new(TechNode::n65(), 0.8);
    pin("is_sigma_d", is.sigma_d(), 0.1071, 1e-12);
    pin(
        "is_delta_v_unit",
        is.delta_v_unit(),
        0.042_279_580_076_146_39,
        1e-9,
    );
    pin("is_k_h", is.k_h(), 9.460_831_902_294_01, 1e-9);
}

#[test]
fn golden_qr_compute_model() {
    let qr = QrModel::new(TechNode::n65(), 3.0);
    pin("qr_sigma_c", qr.sigma_c_rel(), 0.046_188_021_535_170_06, 1e-12);
    pin(
        "qr_sigma_theta",
        qr.sigma_theta_volts(),
        1.174_734_012_447_073e-3,
        1e-12,
    );
    pin("qr_inj_a", qr.inj_a_rel(), 0.030_999_999_999_999_996, 1e-12);
    pin("qr_inj_b", qr.inj_b_rel(), 0.051_666_666_666_666_666, 1e-12);
}

#[test]
fn golden_binomial_clip_moment_at_reference_headroom() {
    // E[(K - k_h)^2; K >= k_h], K ~ Bin(512, 1/4), k_h = k_h(0.8 V):
    // the headroom-collapse moment behind Fig. 9(a)'s N_max cliff.
    let k_h = QsModel::new(TechNode::n65(), 0.8).k_h();
    pin(
        "binclip_512",
        binomial_clip_moment(512, 0.25, k_h),
        7_157.107_451_089_362,
        1e-9,
    );
}

#[test]
fn golden_qs_arch_noise_decomposition() {
    let (w, x) = uni();
    let arch = QsArch::new(QsModel::new(TechNode::n65(), 0.8));
    // Below N_max (N = 128): mismatch-limited, ~18.7 dB.
    let nb = arch.noise(&OpPoint::new(128, 6, 6, 8), &w, &x);
    pin("qs_snr_a_128", nb.snr_a_db(), 18.664_432_739_236_958, 1e-9);
    pin(
        "qs_snr_a_total_128",
        nb.snr_a_total_db(),
        18.568_060_899_934_242,
        1e-9,
    );
    // Above N_max (N = 512): headroom clipping collapses the SNR.
    let nb = arch.noise(&OpPoint::new(512, 6, 6, 8), &w, &x);
    pin("qs_snr_a_512", nb.snr_a_db(), -17.474_086_834_415_637, 1e-9);
    pin(
        "qs_snr_a_total_512",
        nb.snr_a_total_db(),
        -17.474_110_544_030_94,
        1e-9,
    );
}

#[test]
fn golden_bank_adder_tech_parameters() {
    // The bank recombination constants the pre-parameterization code
    // hard-coded in arch::Banked: 5 fJ per two-input add and 50 ps per
    // tree stage at 65 nm, now TechNode parameters that scale with the
    // node (pinned exactly — they feed every banked energy/delay form).
    assert_eq!(TechNode::n65().e_bank_add, 5e-15);
    assert_eq!(TechNode::n65().t_bank_add(), 50e-12);
    pin("e_bank_add_22", TechNode::n22().e_bank_add, 1.1e-15, 1e-12);
    pin("t_bank_add_7", TechNode::n7().t_bank_add(), 11e-12, 1e-12);
}

#[test]
fn golden_banked_512_row_4_bank_reference() {
    // QS-Arch at the 512-row reference (V_WL = 0.8, Bx = Bw = 6) split
    // over 4 banks of 128 rows: the banked SNR_A equals the 128-row
    // pin (both signal and noise scale by the bank count), the MPC
    // assignment is the per-bank one, and energy/delay/area carry the
    // 4x replication plus the adder tree.
    let (w, x) = uni();
    let arch = Banked::new(Box::new(QsArch::new(QsModel::new(TechNode::n65(), 0.8))), 4);
    let op = OpPoint::new(512, 6, 6, 8).with_banks(4);
    let nb = arch.noise(&op, &w, &x);
    pin("banked512_4_snr_a_total", nb.snr_a_total_db(), 18.568_060_899_934_242, 1e-9);
    assert_eq!(arch.b_adc_min(&op, &w, &x), 6, "per-bank MPC assignment");
    pin(
        "banked512_4_energy_fixed8",
        arch.energy(&op, AdcCriterion::Fixed(8), &w, &x).total(),
        1.546_130_088_567_185_4e-10,
        1e-9,
    );
    pin("banked512_4_delay", arch.delay(&op), 4.9e-9, 1e-9);
    pin("banked512_4_area", arch.area(&op).total_mm2(), 4.361_342e-3, 1e-9);
}

#[test]
fn golden_area_closed_forms_at_reference() {
    // Table III geometry -> mm² at the 512-row reference shape
    // (Bx = Bw = 6, B_ADC = 8, 65 nm; C_o = 3 fF for QR/CM).
    let op = OpPoint::new(512, 6, 6, 8);
    let qs = QsArch::new(QsModel::new(TechNode::n65(), 0.8));
    let qr = QrArch::new(QrModel::new(TechNode::n65(), 3.0));
    let cm = CmArch::new(
        QsModel::new(TechNode::n65(), 0.8),
        QrModel::new(TechNode::n65(), 3.0),
    );
    pin("qs_area_512", qs.area(&op).total_mm2(), 2.609_054e-3, 1e-9);
    pin("qr_area_512", qr.area(&op).total_mm2(), 8.678_904e-3, 1e-9);
    pin("cm_area_512", cm.area(&op).total_mm2(), 4.172_116e-3, 1e-9);
    // the SAR slice itself (per-bit logic + 2^B cap-DAC)
    pin("adc_um2_8b_65nm", imclim::area::adc_um2(&TechNode::n65(), 8), 94.42, 1e-9);
    // area is V_WL/C_o-knob-independent except through the caps
    let qs_lo = QsArch::new(QsModel::new(TechNode::n65(), 0.6));
    assert_eq!(
        qs.area(&op).total_mm2().to_bits(),
        qs_lo.area(&op).total_mm2().to_bits()
    );
}

#[test]
fn golden_qr_arch_noise_decomposition() {
    let (w, x) = uni();
    let arch = QrArch::new(QrModel::new(TechNode::n65(), 3.0));
    // The refined QR noise model is N-independent in SNR_a (both signal
    // and noise scale linearly with N) — pin it at the 512-row reference.
    let nb = arch.noise(&OpPoint::new(512, 6, 6, 8), &w, &x);
    pin("qr_snr_a_512", nb.snr_a_db(), 22.205_072_260_460_95, 1e-9);
    pin(
        "qr_snr_a_total_512",
        nb.snr_a_total_db(),
        21.990_261_132_279_12,
        1e-9,
    );
    assert_eq!(nb.sigma_eta_h2, 0.0, "QR has no headroom clipping");
    let nb128 = arch.noise(&OpPoint::new(128, 6, 6, 8), &w, &x);
    pin("qr_snr_a_128", nb128.snr_a_db(), 22.205_072_260_460_95, 1e-9);
}
